"""KV caches for the live engine: paged block-table pool (default) and
the dense per-slot layout (``paged=False`` fallback).

``PagedKVCache`` is the paper's Tier-0 block layout made live: KV state
lives in a global pool of fixed-size pages ([L, n_pages, page, ...]),
each decode slot owns a block table of page indices, and the Pallas
paged-attention kernels (kernels/paged_attention.py,
kernels/mla_paged_decode.py) read through that indirection during
batched decode.  Pages are refcounted (serving/block_allocator.py):
radix-prefix hits map the prefix's physical pages straight into the new
request's block table (copy-on-write sharing — zero bytes moved), and
the PredictiveCacheManager pins the pages of every tier-0-resident
prompt block so they survive request completion for cross-request reuse.

``SlotKVCache`` keeps the original contiguous per-slot DecodeState for
A/B comparison and for families without a paged decode path (hybrid,
RWKV, enc-dec, VLM).

Both caches speak the same engine-facing API (see ``_KVCacheBase``):
    acquire / release / free_slots / set_length
    write_prefill / write_range / inject_block / prefix_kv
    extract_block / evict_slot_to_payload / restore_slot
Block payloads (numpy, [2, L, n, Hkv, hd] or MLA [1, L, n, dl+dr]) are
the currency of the multi-tier hierarchy — identical in both layouts,
so tier demotion/promotion is layout-agnostic.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import MLA, ModelConfig
from repro.core.tiers import CapacityError
from repro.models.model import Model
from repro.serving.block_allocator import BlockAllocator


@dataclass
class SlotInfo:
    request_id: int = -1
    length: int = 0
    active: bool = False


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_pool(arr, pids, offs, data):
    """Scatter ``data`` [L, W, ...] into pool ``arr`` [L, N, page, ...]
    at (pids[w], offs[w]).  Jitted with the pool donated: the update
    runs in place instead of copying the whole pool per write (the
    eager ``.at[].set`` both copied and re-compiled for every distinct
    token count — the compile storm that dominated replay wall-clock
    once the kernels themselves were compiled).  The compile cache is
    module-level, so every engine/replica with the same pool and
    chunk-buffer shapes shares one compilation."""
    return arr.at[:, pids, offs].set(data.astype(arr.dtype))


class _KVCacheBase:
    """Slot bookkeeping + payload conversion shared by both layouts.

    Subclasses provide ``write_range`` / ``extract_block`` /
    ``set_length``; everything here is layout-agnostic."""

    cfg: ModelConfig
    slots: List[SlotInfo]

    # -- slots --------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def acquire(self, request_id: int, length: int) -> int:
        for i, s in enumerate(self.slots):
            if not s.active:
                self.slots[i] = SlotInfo(request_id, length, True)
                return i
        raise RuntimeError("no free slot")

    # -- payloads -----------------------------------------------------------
    def _payload_state(self, payload: np.ndarray) -> Dict:
        if self.cfg.attention_variant == MLA:
            return {"latent": jnp.asarray(payload[0])[:, None]}
        return {"k": jnp.asarray(payload[0])[:, None],
                "v": jnp.asarray(payload[1])[:, None]}

    def write_prefill(self, slot: int, state1: Dict, length: int) -> None:
        """Copy a batch-1 prefill state into slot `slot`."""
        self.write_range(slot, state1, 0, length)
        self.set_length(slot, length)

    def inject_block(self, slot: int, payload: np.ndarray,
                     start: int) -> int:
        """Write one reused block payload at token offset `start`."""
        n = payload.shape[2]
        self.write_range(slot, self._payload_state(payload), start, n)
        return n

    def write_chunk(self, slot: int, state1: Dict, offset: int,
                    n_tokens: int) -> None:
        """Incremental chunked-prefill write: scatter the first
        ``n_tokens`` of a prefill chunk's KV at token ``offset`` and
        advance the slot's valid length (pad positions past the valid
        suffix are never written)."""
        self.write_range(slot, state1, offset, n_tokens)
        self.set_length(slot, offset + n_tokens)

    # -- preemption ---------------------------------------------------------
    def evict_slot_to_payload(self, slot: int) -> Tuple[np.ndarray, int]:
        """Preemption: extract the whole slot state for tier demotion."""
        length = self.slots[slot].length
        payload = self.extract_block(slot, 0, length)
        return payload, length

    def restore_slot(self, slot: int, payload: np.ndarray,
                     length: int) -> None:
        self.inject_block(slot, payload, 0)
        self.set_length(slot, length)

    # -- decode bookkeeping -------------------------------------------------
    def advance(self, slot: int) -> None:
        """Post-decode length advance.  The decode step already advanced
        the device-side length, so this only moves the host mirror — it
        must NOT invalidate a cached device state (``PagedKVCache``
        overrides the invalidating ``set_length`` path for exactly this
        reason)."""
        self.slots[slot].length += 1


class SlotKVCache(_KVCacheBase):
    """Fixed decode slots over the model's contiguous DecodeState."""

    def __init__(self, model: Model, n_slots: int, max_len: int):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.state = model.init_decode_state(n_slots, max_len)
        self.slots = [SlotInfo() for _ in range(n_slots)]

    # ------------------------------------------------------------------
    def release(self, slot: int) -> None:
        self.slots[slot] = SlotInfo()
        self.state["lengths"] = self.state["lengths"].at[slot].set(0)

    def set_length(self, slot: int, length: int) -> None:
        self.slots[slot].length = length
        self.state["lengths"] = self.state["lengths"].at[slot].set(length)

    # ------------------------------------------------------------------
    # moving KV between the slot cache and block payloads (numpy)
    # ------------------------------------------------------------------
    def write_range(self, slot: int, state1: Dict, start: int,
                    n_tokens: int) -> None:
        """Copy a batch-1 KV state into positions [start, start+n)."""
        if self.cfg.attention_variant == MLA:
            self.state["latent"] = self.state["latent"].at[
                :, slot, start:start + n_tokens].set(
                state1["latent"][:, 0, :n_tokens])
        else:
            self.state["k"] = self.state["k"].at[
                :, slot, start:start + n_tokens].set(
                state1["k"][:, 0, :n_tokens])
            self.state["v"] = self.state["v"].at[
                :, slot, start:start + n_tokens].set(
                state1["v"][:, 0, :n_tokens])

    def extract_block(self, slot: int, start: int, n_tokens: int) -> np.ndarray:
        """Slot KV -> block payload [2, L, n_tokens, H, hd] (or MLA
        [1, L, n_tokens, dl+dr])."""
        if self.cfg.attention_variant == MLA:
            lat = self.state["latent"][:, slot, start:start + n_tokens]
            return np.asarray(lat)[None]
        k = np.asarray(self.state["k"][:, slot, start:start + n_tokens])
        v = np.asarray(self.state["v"][:, slot, start:start + n_tokens])
        return np.stack([k, v])

    def prefix_kv(self, slot: int, length: int):
        """Cached prefix (k, v) for suffix-prefill, batch dim restored."""
        if self.cfg.attention_variant == MLA:
            return (self.state["latent"][:, slot:slot + 1, :length],)
        return (self.state["k"][:, slot:slot + 1, :length],
                self.state["v"][:, slot:slot + 1, :length])


# ===========================================================================
# Paged block-table cache (the default serving path)
# ===========================================================================
class PagedKVCache(_KVCacheBase):
    """Global page pool + per-slot block tables + CoW prefix sharing.

    Pool layout (page 0 is a reserved scratch page that absorbs the
    decode-step writes of inactive slots):

        GQA/MHA/MQA:  k_pages, v_pages  [L, n_pages, page, Hkv, hd]
        MLA:          latent_pages      [L, n_pages, page, dl+dr]

    Block tables are host numpy ([n_slots, pages_per_slot] int32, 0 =
    unmapped); `decode_state()` snapshots them (plus per-slot lengths)
    into device arrays for `Model.decode_step_paged`, which scatters the
    new token's KV into the pool and attends through the Pallas paged
    kernels.
    """

    def __init__(self, model: Model, n_slots: int, max_len: int, *,
                 page_tokens: int = 64, reserve_pages: Optional[int] = None,
                 dtype=jnp.bfloat16):
        cfg = model.cfg
        self.model = model
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.page = page_tokens
        self.pages_per_slot = -(-max_len // page_tokens)
        if reserve_pages is None:
            # headroom for manager-pinned prefix blocks that outlive slots
            reserve_pages = max(8, 2 * self.pages_per_slot)
        self.n_pages = 1 + n_slots * self.pages_per_slot + reserve_pages
        self.allocator = BlockAllocator(self.n_pages, reserved=(0,))
        self.mla = cfg.attention_variant == MLA
        L = cfg.n_layers
        if self.mla:
            d = cfg.d_latent + cfg.d_rope
            self.pools = {"latent_pages": jnp.zeros(
                (L, self.n_pages, self.page, d), dtype)}
        else:
            hkv = max(cfg.n_kv_heads, 1)
            shape = (L, self.n_pages, self.page, hkv, cfg.hd)
            self.pools = {"k_pages": jnp.zeros(shape, dtype),
                          "v_pages": jnp.zeros(shape, dtype)}
        self.tables = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self._mapped = [0] * n_slots           # contiguous mapped page count
        self.slots = [SlotInfo() for _ in range(n_slots)]
        self.block_pages: Dict[str, List[int]] = {}
        # device-state cache for the fused step loop: in steady-state
        # decode (same slot set, no table/length/pool mutation since the
        # last step) the state returned by the previous fused decode is
        # handed straight back — no table copy, no masking pass, no
        # host->device upload.  ``state_version`` is bumped by every
        # host-side mutation that would make the cached snapshot stale.
        self.state_version = 0
        self._cached_state: Optional[Dict] = None
        self._cached_slots: Optional[frozenset] = None
        self._cached_version = -1
        self.state_reuses = 0      # decode_state served from the cache
        self.state_rebuilds = 0    # full snapshot builds

    # ------------------------------------------------------------------
    # slot lifecycle
    # ------------------------------------------------------------------
    def release(self, slot: int) -> None:
        for pi in range(self._mapped[slot]):
            self.allocator.deref(int(self.tables[slot, pi]))
        self.tables[slot, :] = 0
        self._mapped[slot] = 0
        self.slots[slot] = SlotInfo()
        self.state_version += 1

    def set_length(self, slot: int, length: int) -> None:
        self.slots[slot].length = length
        self.state_version += 1

    # ------------------------------------------------------------------
    # page mapping
    # ------------------------------------------------------------------
    def _alloc(self, n: int) -> List[int]:
        """Allocate with backpressure: a full pool first reclaims pages
        pinned for manager blocks (oldest registrations first) — the
        blocks' host payloads survive in the manager, so prefix hits
        degrade from CoW page-sharing to payload injection instead of
        the engine crashing."""
        try:
            return self.allocator.alloc(n)
        except CapacityError:
            for bid in list(self.block_pages):
                self.drop_block_pages(bid)
                if self.allocator.n_free >= n:
                    break
            return self.allocator.alloc(n)

    def _ensure_pages(self, slot: int, n_tokens: int) -> None:
        need = -(-n_tokens // self.page)
        cur = self._mapped[slot]
        if need <= cur:
            return
        for i, pid in enumerate(self._alloc(need - cur)):
            self.tables[slot, cur + i] = pid
        self._mapped[slot] = need
        self.state_version += 1

    def ensure_private(self, slot: int, page_index: int) -> None:
        """Copy-on-write: give the slot a private copy of a shared page
        before any write lands on it."""
        pid = int(self.tables[slot, page_index])
        if pid == 0 or self.allocator.refcount(pid) <= 1:
            return
        new = self._alloc(1)[0]
        for key, arr in self.pools.items():
            self.pools[key] = arr.at[:, new].set(arr[:, pid])
        self.tables[slot, page_index] = new
        self.allocator.deref(pid)
        self.allocator.note_cow_copy()
        self.state_version += 1

    # ------------------------------------------------------------------
    # CoW prefix sharing with the cache manager
    # ------------------------------------------------------------------
    def can_share(self, block_id: str) -> bool:
        return block_id in self.block_pages

    def share_block(self, slot: int, block_id: str, start: int) -> int:
        """Map a pool-resident block's pages into the slot's table
        (refcount bump — no bytes move).  Returns tokens mapped."""
        pids = self.block_pages[block_id]
        assert start % self.page == 0, "shared blocks must be page-aligned"
        pi0 = start // self.page
        for j, pid in enumerate(pids):
            self.allocator.ref(pid, share=True)
            self.tables[slot, pi0 + j] = pid
        self._mapped[slot] = max(self._mapped[slot], pi0 + len(pids))
        self.state_version += 1
        return len(pids) * self.page

    def register_block_pages(self, block_id: str, slot: int, start: int,
                             n_tokens: int) -> None:
        """Pin the pages backing a newly-registered prompt block so they
        survive the slot for cross-request reuse."""
        if block_id in self.block_pages:
            return
        assert start % self.page == 0 and n_tokens % self.page == 0
        pids = [int(self.tables[slot, pi])
                for pi in range(start // self.page,
                                (start + n_tokens) // self.page)]
        for pid in pids:
            self.allocator.ref(pid)
        self.block_pages[block_id] = pids

    def drop_block_pages(self, block_id: str) -> None:
        for pid in self.block_pages.pop(block_id, ()):
            self.allocator.deref(pid)

    def gc_blocks(self, manager) -> int:
        """Unpin pages of blocks that left tier 0 (demoted / evicted /
        released by the PredictiveCacheManager)."""
        dropped = 0
        for bid in list(self.block_pages):
            if bid not in manager.metas or manager.hierarchy.locate(bid) != 0:
                self.drop_block_pages(bid)
                dropped += 1
        return dropped

    # ------------------------------------------------------------------
    # writes
    # ------------------------------------------------------------------
    def write_range(self, slot: int, state1: Dict, start: int,
                    n_tokens: int) -> None:
        """Scatter a batch-1 KV state into positions [start, start+n),
        allocating (and CoW-privatizing) pages as needed.  One donated
        jitted scatter per pool tensor (``_scatter_pool``): the index
        arrays span the data buffer's FULL width (chunk buffer / block
        payload), with entries past ``n_tokens`` directed at the
        reserved scratch page 0 — so the scatter shape depends only on
        the buffer shape, compiles once per buffer (not once per token
        count), and the hot chunked-prefill path hits one cached
        executable for every chunk."""
        self._ensure_pages(slot, start + n_tokens)
        for pi in range(start // self.page,
                        (start + n_tokens - 1) // self.page + 1):
            self.ensure_private(slot, pi)
        if self.mla:
            items = [("latent_pages", state1["latent"][:, 0])]
        else:
            items = [("k_pages", state1["k"][:, 0]),
                     ("v_pages", state1["v"][:, 0])]
        width = items[0][1].shape[1]
        pos = np.arange(start, start + n_tokens)
        pids = np.zeros(width, np.int32)
        offs = np.zeros(width, np.int32)
        pids[:n_tokens] = self.tables[slot, pos // self.page]
        offs[:n_tokens] = pos % self.page
        pid_arr, off_arr = jnp.asarray(pids), jnp.asarray(offs)
        for key, data in items:
            self.pools[key] = _scatter_pool(self.pools[key], pid_arr,
                                            off_arr, data)
        self.state_version += 1    # pool arrays replaced

    def ensure_pages_at(self, slot: int, page_indices: Sequence[int]) -> None:
        """Allocate pages for unmapped (hole) table entries among
        ``page_indices``.  Segment assembly maps resumed segments beyond
        the contiguous frontier (``share_block``), which advances
        ``_mapped`` past gap pages that are still table-entry 0 — the
        contiguous ``_ensure_pages`` sweep would skip those holes and
        gap writes would land on the scratch page."""
        missing = [pi for pi in page_indices
                   if int(self.tables[slot, pi]) == 0]
        if not missing:
            return
        for pi, pid in zip(missing, self._alloc(len(missing))):
            self.tables[slot, pi] = pid
        self._mapped[slot] = max(self._mapped[slot],
                                 max(page_indices) + 1)
        self.state_version += 1

    def write_chunk_positions(self, slot: int, state1: Dict,
                              positions: Sequence[int]) -> None:
        """Scatter the first ``len(positions)`` tokens of a segment-
        prefill chunk at the given absolute token positions (ascending,
        possibly non-contiguous: a chunk may span several prompt gaps
        around resumed segments).  Buffer entries past the valid count
        are directed at the reserved scratch page, same as
        ``write_range``.  Does NOT advance the slot length — the caller
        moves the contiguous frontier (``set_length``) once adjoining
        resumed segments merge with it."""
        n = len(positions)
        if n == 0:
            return
        pos = np.asarray(positions, np.int64)
        touched = sorted({int(p) for p in pos // self.page})
        self.ensure_pages_at(slot, touched)
        for pi in touched:
            self.ensure_private(slot, pi)
        if self.mla:
            items = [("latent_pages", state1["latent"][:, 0])]
        else:
            items = [("k_pages", state1["k"][:, 0]),
                     ("v_pages", state1["v"][:, 0])]
        width = items[0][1].shape[1]
        pids = np.zeros(width, np.int32)
        offs = np.zeros(width, np.int32)
        pids[:n] = self.tables[slot, pos // self.page]
        offs[:n] = pos % self.page
        pid_arr, off_arr = jnp.asarray(pids), jnp.asarray(offs)
        for key, data in items:
            self.pools[key] = _scatter_pool(self.pools[key], pid_arr,
                                            off_arr, data)
        self.state_version += 1    # pool arrays replaced

    # ------------------------------------------------------------------
    # reads
    # ------------------------------------------------------------------
    def _gather(self, key: str, slot: int, start: int, n_tokens: int):
        """Pool pages -> contiguous [L, n_tokens, ...] (device array)."""
        p0 = start // self.page
        p1 = (start + n_tokens - 1) // self.page
        pids = np.asarray(self.tables[slot, p0:p1 + 1])
        arr = self.pools[key][:, pids]              # [L, np, page, ...]
        L = arr.shape[0]
        flat = arr.reshape((L, -1) + arr.shape[3:])
        rel = start - p0 * self.page
        return flat[:, rel:rel + n_tokens]

    def extract_block(self, slot: int, start: int,
                      n_tokens: int) -> np.ndarray:
        if self.mla:
            lat = self._gather("latent_pages", slot, start, n_tokens)
            return np.asarray(lat)[None]
        k = np.asarray(self._gather("k_pages", slot, start, n_tokens))
        v = np.asarray(self._gather("v_pages", slot, start, n_tokens))
        return np.stack([k, v])

    def prefix_kv(self, slot: int, length: int):
        if self.mla:
            return (self._gather("latent_pages", slot, 0, length)[:, None],)
        return (self._gather("k_pages", slot, 0, length)[:, None],
                self._gather("v_pages", slot, 0, length)[:, None])

    # ------------------------------------------------------------------
    # decode-step interface
    # ------------------------------------------------------------------
    def _prepare_decode_pages(self, include: Optional[set]) -> None:
        """Guarantee every decoding slot a private page for the incoming
        token.  Page needs are gathered host-side first and satisfied in
        ONE allocator call for the whole step (the per-slot
        ``_ensure_pages`` loop paid one allocator lock round-trip per
        request per step)."""
        needs = []
        for i, s in enumerate(self.slots):
            if not s.active or (include is not None and i not in include):
                continue
            need = -(-(s.length + 1) // self.page) - self._mapped[i]
            if need > 0:
                needs.append((i, need))
        if needs:
            pids = self._alloc(sum(n for _, n in needs))
            j = 0
            for i, need in needs:
                cur = self._mapped[i]
                for t in range(need):
                    self.tables[i, cur + t] = pids[j]
                    j += 1
                self._mapped[i] = cur + need
            self.state_version += 1
        for i, s in enumerate(self.slots):
            if not s.active or (include is not None and i not in include):
                continue
            self.ensure_private(i, s.length // self.page)

    def decode_state(self, decode_slots: Optional[Sequence[int]] = None,
                     reuse: bool = False) -> Dict:
        """Snapshot for Model.decode_step_paged.  Guarantees every
        decoding slot has a private page mapped for the incoming token.

        ``decode_slots`` restricts the batch to those slots (the mixed
        token-budget step: slots mid-chunked-prefill stay out): excluded
        rows get a zeroed block table and length 0, so the kernel's
        per-row KV write lands on the reserved scratch page instead of
        the slot's real (possibly CoW-shared) prefix pages.

        ``reuse=True`` (fused step loop): if the previous fused step's
        returned state is cached, covers the same slot set, and no
        host-side mutation happened since (``state_version``), hand it
        straight back — the caller donates it into the step closure and
        ``absorb`` re-caches the result.  Steady-state decode then runs
        with zero per-step table copies or host->device uploads."""
        include = (None if decode_slots is None else set(decode_slots))
        self._prepare_decode_pages(include)
        if reuse and include is not None:
            key = frozenset(include)
            if (self._cached_state is not None
                    and self._cached_slots == key
                    and self._cached_version == self.state_version):
                state = self._cached_state
                self._cached_state = None  # donated into the closure
                self.state_reuses += 1
                return state
        self.state_rebuilds += 1
        tables = self.tables
        lengths = np.asarray(
            [s.length if s.active and (include is None or i in include)
             else 0 for i, s in enumerate(self.slots)], np.int32)
        if include is not None:
            tables = tables.copy()
            for i in range(self.n_slots):
                if i not in include:
                    tables[i, :] = 0
        state = dict(self.pools)
        state["block_tables"] = jnp.asarray(tables)
        state["lengths"] = jnp.asarray(lengths)
        return state

    def chunk_state(self, slot: int) -> Dict:
        """Snapshot for Model.prefill_chunk: the page pools plus this
        slot's block-table row (batch dim 1)."""
        state = dict(self.pools)
        state["block_table"] = jnp.asarray(self.tables[slot:slot + 1])
        return state

    def absorb(self, new_state: Dict,
               decode_slots: Optional[Sequence[int]] = None) -> None:
        """Take back the (donated) pool arrays after a decode step.

        With ``decode_slots`` (fused path) the whole returned state —
        pools, tables, per-row lengths already advanced on device — is
        cached for ``decode_state(reuse=True)`` next step."""
        for key in self.pools:
            self.pools[key] = new_state[key]
        self.state_version += 1    # pool arrays replaced
        if decode_slots is not None:
            self._cached_state = new_state
            self._cached_slots = frozenset(decode_slots)
            self._cached_version = self.state_version
        else:
            self._cached_state = None
