"""Multi-replica serving cluster: N share-nothing ``ServingEngine``
replicas behind a pluggable request router, with mid-run failover and
elastic scale-out (paper §VII scaling / graceful degradation).

Promoted out of ``launch/serve.py`` (which is now a thin CLI over this
module) so the trace replay can evaluate fleet-level behaviour — the
paper's throughput and cost claims are fleet-level: consistent-hash
session affinity keeps each replica's prefix cache warm, and failover
re-prefills the lost KV on the successor replica.

Routing policies (``make_router``):

  * ``affine`` — consistent-hash session affinity over the same ring
    implementation as the RDMA tier (``core/tiers.ConsistentHashRing``).
    A session's every turn lands on the same replica, so cross-turn
    radix-prefix reuse keeps working; node join/leave remaps ~1/n of
    the session space.
  * ``round_robin`` — classic load spreading, deliberately blind to
    sessions: consecutive turns of one conversation land on different
    replicas, fragmenting the prefix cache.  This is the naive baseline
    the cluster replay (``benchmarks/run.py --table cluster``) measures
    the affinity win against.
  * ``least_loaded`` — route to the replica with the fewest live
    requests (waiting + running + preempted + blocked); ties break by
    name for determinism.
  * ``prefix`` — prefix-cache-aware routing: probe every replica's
    radix tree (non-mutating) for the request's tokens and route to the
    replica holding the longest matching prefix; with no match anywhere
    (or no tokens available) fall back to least-loaded.  A session
    sticks to its replica implicitly — its turn-1 prefix registers
    there, so turn 2's probe finds it — and, unlike hash affinity, two
    sessions sharing a template prefix co-locate on the replica that
    already holds it.

**Fleet-shared tier 4** (``shared_tier=True``): the cluster owns one
``core/tiers.FleetKVStore`` — a content-addressed RDMA namespace — and
binds every replica's tier 4 to it (``SharedTierView``), so a popular
template's blocks occupy fabric bytes once fleet-wide and a replica can
import a prefix another replica published (a tier-4 fetch instead of a
re-prefill).  A failed replica's teardown releases only ITS references;
shared bytes other replicas still use stay resident.

**Scale-out warm-up** (``add_replica(warmup=True)``): before the joiner
takes traffic, sessions the router remaps onto it get their registered
prefix blocks (payloads included) pushed from their previous replica,
so the first post-join turn hits hot instead of paying a re-prefill
TTFT spike.

Failover (``fail_replica``): the dead replica's scheduler is drained —
waiting, running, preempted AND transfer-blocked requests — and every
request is re-dispatched through the router after
``Request.reset_for_redispatch()`` wipes the per-request accounting
that referred to the dead engine (generated tokens, slot, block ids,
chunk cursor, prefix/hot hit counts).  The dead engine's transfer
worker is closed and its cache-manager/tier registrations are released
(``ServingEngine.release_resources``) instead of leaking; its
``ManagerStats`` are retained for fleet aggregation.  The successor
replica re-prefills the lost KV from scratch — the recomputation tax
the paper's graceful-degradation story pays, surfaced here as
``reprefill_tokens``.

Scale-out (``add_replica``): a new share-nothing engine joins the
router; under ``affine`` routing ~1/n of the session space remaps to it
(cold prefix cache until those sessions resubmit their prefixes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.cache_manager import ManagerStats
from repro.core.tiers import ConsistentHashRing, FleetKVStore
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------
class RoutingPolicy:
    """Maps a session key to a replica name.  Stateful: policies are
    told about replica join/leave so failover and scale-out re-route
    without the cluster knowing policy internals.  ``tokens`` (the
    request's prompt tokens, when the dispatcher has them) lets
    content-aware policies inspect the prefix; hash/load policies
    ignore it."""

    name = "?"

    def add_replica(self, replica: str) -> None:
        raise NotImplementedError

    def remove_replica(self, replica: str) -> None:
        raise NotImplementedError

    def route(self, key: str, engines: Dict[str, "ServingEngine"] = None,
              tokens: Optional[Sequence[int]] = None) -> str:
        raise NotImplementedError


class SessionAffinityRouter(RoutingPolicy):
    """Consistent-hash session affinity (the paper's default).

    ``salt`` seeds the key hashing, so tests can pin — or deliberately
    vary — the session→replica assignment without renaming replicas.
    """

    name = "affine"

    def __init__(self, vnodes: int = 64, salt: str = ""):
        self.ring = ConsistentHashRing(vnodes=vnodes)
        self.salt = salt

    def add_replica(self, replica: str) -> None:
        self.ring.add_node(replica)

    def remove_replica(self, replica: str) -> None:
        self.ring.remove_node(replica)

    def route(self, key: str, engines=None, tokens=None) -> str:
        return self.ring.lookup(f"{self.salt}:{key}" if self.salt else key)


class RoundRobinRouter(RoutingPolicy):
    """Session-blind load spreading — the fragmentation baseline."""

    name = "round_robin"

    def __init__(self):
        self._replicas: List[str] = []
        self._next = 0

    def add_replica(self, replica: str) -> None:
        if replica not in self._replicas:
            self._replicas.append(replica)
            self._replicas.sort()

    def remove_replica(self, replica: str) -> None:
        if replica in self._replicas:
            self._replicas.remove(replica)

    def route(self, key: str, engines=None, tokens=None) -> str:
        if not self._replicas:
            raise RuntimeError("no replicas")
        out = self._replicas[self._next % len(self._replicas)]
        self._next += 1
        return out


class LeastLoadedRouter(RoutingPolicy):
    """Route to the replica with the fewest live requests."""

    name = "least_loaded"

    def __init__(self):
        self._replicas: List[str] = []

    def add_replica(self, replica: str) -> None:
        if replica not in self._replicas:
            self._replicas.append(replica)
            self._replicas.sort()

    def remove_replica(self, replica: str) -> None:
        if replica in self._replicas:
            self._replicas.remove(replica)

    @staticmethod
    def _load(eng: "ServingEngine") -> int:
        return eng.scheduler.live_count()

    def route(self, key: str, engines: Dict[str, "ServingEngine"] = None,
              tokens=None) -> str:
        if not self._replicas:
            raise RuntimeError("no replicas")
        return min(self._replicas, key=lambda n: (self._load(engines[n]), n))


class PrefixAwareRouter(RoutingPolicy):
    """Prefix-cache-aware routing: probe every replica's radix tree
    (non-mutating) for the request's tokens; the replica holding the
    longest live matching prefix wins (ties break by name).  With no
    match anywhere — or no tokens supplied — fall back to least-loaded.

    Sessions stick implicitly: turn 1 registers its prefix on whichever
    replica it lands, so turn 2's probe finds it there.  Unlike hash
    affinity, sessions sharing a template prefix co-locate."""

    name = "prefix"

    def __init__(self):
        self._replicas: List[str] = []

    def add_replica(self, replica: str) -> None:
        if replica not in self._replicas:
            self._replicas.append(replica)
            self._replicas.sort()

    def remove_replica(self, replica: str) -> None:
        if replica in self._replicas:
            self._replicas.remove(replica)

    def route(self, key: str, engines: Dict[str, "ServingEngine"] = None,
              tokens: Optional[Sequence[int]] = None) -> str:
        if not self._replicas:
            raise RuntimeError("no replicas")
        if tokens is not None and engines:
            best, best_n = "", 0
            for n in self._replicas:
                depth = engines[n].manager.peek_prefix_blocks(tokens)
                if depth > best_n:
                    best, best_n = n, depth
            if best_n > 0:
                return best
        if not engines:
            return self._replicas[0]
        return min(self._replicas,
                   key=lambda n: (engines[n].scheduler.live_count(), n))


ROUTERS: Dict[str, Callable[[], RoutingPolicy]] = {
    "affine": SessionAffinityRouter,
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "prefix": PrefixAwareRouter,
}


def make_router(policy: str, **kw) -> RoutingPolicy:
    if policy not in ROUTERS:
        raise ValueError(f"unknown routing policy {policy!r} "
                         f"(have {sorted(ROUTERS)})")
    return ROUTERS[policy](**kw)


# ---------------------------------------------------------------------------
# The cluster
# ---------------------------------------------------------------------------
class ReplicaCluster:
    """N share-nothing engine replicas + pluggable request routing.

    ``engine_factory`` builds one replica engine; the default constructs
    ``ServingEngine(cfg, engine_cfg)`` (params re-init deterministically
    — replicas share nothing).  The trace replay passes a factory that
    applies its replay tier specs and virtual-clock engine config.
    """

    def __init__(self, cfg=None, engine_cfg: Optional[EngineConfig] = None,
                 n_replicas: int = 2, *, routing: str = "affine",
                 engine_factory: Optional[Callable[[], ServingEngine]] = None,
                 router: Optional[RoutingPolicy] = None,
                 name_prefix: str = "replica",
                 shared_tier: bool = False,
                 rdma_nodes: Sequence[str] = ("node0", "node1",
                                              "node2", "node3")):
        if engine_factory is None:
            if cfg is None:
                raise ValueError("need cfg+engine_cfg or engine_factory")
            engine_factory = lambda: ServingEngine(cfg, engine_cfg)  # noqa: E731
        self._factory = engine_factory
        self._prefix = name_prefix
        self._next_replica = 0
        self.router = router if router is not None else make_router(routing)
        self.engines: Dict[str, ServingEngine] = {}
        # fleet-shared tier 4: one content-addressed namespace every
        # replica's TierHierarchy binds (created lazily from the first
        # replica's tier-4 spec so replay tier overrides apply)
        self._shared_tier = shared_tier
        self._rdma_nodes = tuple(rdma_nodes)
        self.fleet_store: Optional[FleetKVStore] = None
        # session → last submitted prompt / serving replica, kept for
        # the scale-out warm-up path (push remapped sessions' hot
        # blocks to a joiner before it takes traffic)
        self._session_prompt: Dict[str, List[int]] = {}
        self._session_replica: Dict[str, str] = {}
        self.warmed_blocks = 0
        self.warmed_sessions = 0
        # failed replicas keep ONLY their ManagerStats and completed
        # count for fleet rollup — retaining the dead engine would keep
        # its params and KV pool (the dominant allocations) alive
        self.failed_stats: Dict[str, ManagerStats] = {}
        self.failed_done: Dict[str, int] = {}
        self.redispatched = 0
        self.reprefill_tokens = 0          # prompt tokens whose KV was lost
        self._anon_ids = 0
        # (request_id, from_replica, to_replica) per failover redispatch
        self.redispatch_log: List[Tuple[int, str, str]] = []
        for _ in range(n_replicas):
            self.add_replica()

    # -- membership ---------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def add_replica(self, name: Optional[str] = None, *,
                    warmup: bool = False) -> str:
        """Join a fresh replica; under affine routing ~1/n of the
        session space remaps onto it.  With ``warmup=True`` the
        sessions the router remaps onto the joiner get their prefix
        blocks (payloads included) pushed from their previous replica
        BEFORE the joiner takes traffic, so the first post-join turn
        hits hot instead of paying a re-prefill TTFT spike."""
        if name is None:
            name = f"{self._prefix}{self._next_replica}"
        self._next_replica += 1
        if name in self.engines or name in self.failed_stats:
            # a failed replica's name stays reserved: reusing it would
            # collide the stats rollups and mark the newcomer failed
            raise ValueError(f"replica {name!r} already exists")
        eng = self._factory()
        if self._shared_tier:
            if self.fleet_store is None:
                spec = next((t.spec for t in eng.manager.hierarchy.tiers
                             if t.spec.tier_id == 4), None)
                if spec is not None:
                    self.fleet_store = FleetKVStore(
                        spec, nodes=self._rdma_nodes)
            if self.fleet_store is not None:
                eng.bind_fleet_store(self.fleet_store, name)
        self.engines[name] = eng
        self.router.add_replica(name)
        if warmup:
            for sid, prompt in self._session_prompt.items():
                src = self._session_replica.get(sid)
                if src is None or src == name or src not in self.engines:
                    continue
                if self.route(sid) != name:
                    continue             # session did not remap to joiner
                n = self._warm_session(sid, prompt, src, name)
                if n:
                    self.warmed_blocks += n
                    self.warmed_sessions += 1
        return name

    def _warm_session(self, sid: str, prompt: List[int],
                      src_name: str, dst_name: str) -> int:
        """Copy one remapped session's registered prefix blocks (with
        payloads) from its previous replica to the joiner.  Returns the
        number of blocks adopted."""
        src = self.engines[src_name].manager
        dst = self.engines[dst_name].manager
        tokens = list(prompt)[:-1]       # engines never cache the last token
        bids = src.match_prefix(tokens)
        if not bids:
            return 0
        bt = src.block_tokens
        payloads = [src._payloads.get(b) for b in bids]
        adopted = dst.adopt_sequence(tokens[:len(bids) * bt], payloads)
        return len(adopted)

    def fail_replica(self, name: str) -> int:
        """Kill a replica: drain every live request (waiting, running,
        preempted, transfer-blocked), reset their per-request accounting,
        re-dispatch through the router, and release the dead engine's
        manager/tier registrations.  Returns the redispatch count."""
        if len(self.engines) <= 1:
            # check BEFORE mutating: there is nowhere to re-dispatch,
            # and popping first would leave an empty, unusable cluster
            raise RuntimeError("cannot fail the last replica")
        eng = self.engines.pop(name)
        self.router.remove_replica(name)
        lost = eng.scheduler.drain_requests()
        for req in lost:
            # KV (including any generated tokens) died with the replica:
            # the successor re-prefills the prompt from scratch
            self.reprefill_tokens += req.prompt_len + len(req.generated)
            req.reset_for_redispatch()
            target = self.route(req.session_id or str(req.request_id),
                                tokens=list(req.prompt)[:-1])
            self.engines[target].scheduler.submit(req)
            if req.session_id is not None:
                self._session_replica[req.session_id] = target
            self.redispatched += 1
            self.redispatch_log.append((req.request_id, name, target))
        eng.manager.sync_fault_stats()
        self.failed_stats[name] = eng.manager.stats
        self.failed_done[name] = len(eng.scheduler.done)
        eng.release_resources()
        return len(lost)

    def cancel_request(self, request: Request) -> bool:
        """Cancel one live request wherever it lives (frontend drain-
        deadline shedding); returns True when an engine released it."""
        for eng in self.engines.values():
            if eng.cancel_request(request):
                return True
        return False

    # -- dispatch -----------------------------------------------------------
    def route(self, session_key: str,
              tokens: Optional[Sequence[int]] = None) -> str:
        return self.router.route(session_key, self.engines, tokens)

    def dispatch(self, prompt, *, session_id: Optional[str] = None,
                 **kw) -> Tuple[str, Request]:
        """Route + submit; returns (replica_name, request).  Session-less
        requests route by a fresh surrogate key so they still spread
        across the ring."""
        key = session_id if session_id is not None \
            else f"anon{self._anon_ids}"
        self._anon_ids += 1
        target = self.route(key, tokens=list(prompt)[:-1])
        if session_id is not None:
            self._session_prompt[session_id] = list(prompt)
            self._session_replica[session_id] = target
        req = self.engines[target].submit(prompt, session_id=session_id,
                                          **kw)
        return target, req

    def submit(self, prompt, *, session_id: Optional[str] = None,
               **kw) -> Request:
        return self.dispatch(prompt, session_id=session_id, **kw)[1]

    # -- stepping -----------------------------------------------------------
    def busy(self) -> List[Tuple[str, ServingEngine]]:
        """Replicas with live work, in stable name order."""
        return [(n, e) for n, e in sorted(self.engines.items())
                if e.scheduler.has_work()]

    def step(self) -> int:
        """One fleet iteration: every busy replica steps once (replicas
        run concurrently in a real deployment).  Returns tokens
        produced fleet-wide."""
        produced = 0
        for _, eng in self.busy():
            produced += eng.step()
        return produced

    def has_work(self) -> bool:
        return any(e.scheduler.has_work() for e in self.engines.values())

    def run(self, max_steps: int = 10_000) -> dict:
        steps = 0
        while steps < max_steps and self.has_work():
            self.step()
            steps += 1
        return self.stats()

    # -- stats --------------------------------------------------------------
    def manager_stats(self, include_failed: bool = True
                      ) -> Dict[str, ManagerStats]:
        """Per-replica ``ManagerStats`` (failed replicas retain theirs
        for fleet aggregation)."""
        out = {}
        for n, e in self.engines.items():
            e.manager.sync_fault_stats()
            out[n] = e.manager.stats
        if include_failed:
            out.update(self.failed_stats)
        return out

    # quarantined beats probing beats degraded beats healthy when two
    # replicas disagree about the same tier id in the fleet rollup
    _HEALTH_RANK = {"healthy": 0, "degraded": 1, "probing": 2,
                    "quarantined": 3}

    def fleet_manager_stats(self) -> ManagerStats:
        """Fleet-wide rollup: field-wise sum over every replica that
        ever served traffic (hit rates derive from the summed counts).
        ``tier_health`` merges worst-state-wins per tier id."""
        agg = ManagerStats()
        for ms in self.manager_stats().values():
            for f in dataclasses.fields(ManagerStats):
                if f.name == "tier_hits":
                    for t, n in ms.tier_hits.items():
                        agg.tier_hits[t] = agg.tier_hits.get(t, 0) + n
                elif f.name == "tier_health":
                    for t, st in ms.tier_health.items():
                        cur = agg.tier_health.get(t, "healthy")
                        if self._HEALTH_RANK.get(st, 0) > \
                                self._HEALTH_RANK.get(cur, 0):
                            agg.tier_health[t] = st
                        else:
                            agg.tier_health.setdefault(t, cur)
                else:
                    setattr(agg, f.name,
                            getattr(agg, f.name) + getattr(ms, f.name))
        return agg

    def stats(self) -> dict:
        agg = {"replicas": {n: e.stats()
                            for n, e in sorted(self.engines.items())},
               "failed_replicas": sorted(self.failed_stats),
               "routing": self.router.name,
               "redispatched": self.redispatched,
               "reprefill_tokens": self.reprefill_tokens,
               "shared_tier": self._shared_tier,
               "warmed_blocks": self.warmed_blocks,
               "warmed_sessions": self.warmed_sessions}
        if self.fleet_store is not None:
            agg["fleet_store"] = self.fleet_store.stats()
        agg["done"] = sum(s["scheduler"]["done"]
                          for s in agg["replicas"].values())
        agg["done"] += sum(self.failed_done.values())
        fleet = self.fleet_manager_stats()
        agg["fleet"] = {"hit_rate_hot": fleet.hit_rate,
                        "accesses": fleet.accesses,
                        "hot_hits_t0": fleet.hot_hits_t0,
                        "hot_hits_t1": fleet.hot_hits_t1,
                        "promotions": fleet.promotions,
                        "demotions": fleet.demotions}
        return agg

    def shutdown(self) -> None:
        for eng in self.engines.values():
            eng.shutdown()
