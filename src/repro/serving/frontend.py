"""Wall-clock serving front-end: concurrent submission, streaming
per-token callbacks, and SLO-aware admission control around a
``ServingEngine`` or ``ReplicaCluster``.

Every number before this module came from a *virtual* clock — the
replay harness validates hit rates and TTFT deltas, but the paper's
headline claims (sub-millisecond TTFT for hot entries, 1.7–2.9x
throughput under load) are claims about a real-time system with
concurrent arrivals.  The front-end is that layer:

  * **submission** is thread-safe and non-blocking: ``submit`` drops a
    ``StreamHandle`` into an inbox and returns immediately; the pump
    loop (a background thread via ``start()``, or the caller's thread
    via ``run_for``/``serve_schedule``) drains it each iteration;
  * **streaming**: after each engine step the pump delivers newly
    generated tokens to each handle's ``on_token(token, index)``
    callback — exactly once per token, in token order — and fires
    ``on_done(handle)`` exactly once when the request completes (or is
    shed);
  * **SLO-aware admission**: each arrival's TTFT is *projected* from
    observable state (prefill backlog, decode occupancy, an EWMA of the
    measured step time); when the projection breaches the configured
    budget the request is queued (bounded) or shed, so the p99 TTFT of
    what the server *accepts* stays under the budget instead of growing
    without bound under open-loop overload.  Goodput / shed accounting
    lives in ``stats()``.

Designed for testability first — wall-clock concurrency is where flaky
tests are born, so every source of nondeterminism is injectable:

  * the **clock** is a parameter (any object with ``monotonic()`` /
    ``sleep(dt)``; the ``time`` module is the default, ``VirtualClock``
    is the deterministic test double), and ``step_time_s`` optionally
    charges a fixed virtual cost per engine step so latency metrics are
    exact integers of steps;
  * ``run_for(n_steps=... | duration_s=...)`` pumps inline on the
    caller's thread — no background thread, no races — which is how the
    deterministic tests drive it;
  * admission decisions are **pure functions** of an
    ``AdmissionSnapshot`` (``admission_decision`` /
    ``projected_ttft_s``), unit-testable without any engine or timing.

The open-loop driver ``serve_schedule`` replays a
``traces/loadgen.py`` arrival schedule: submissions happen when the
clock passes each arrival's timestamp (never earlier), and a handle's
latency is measured from the *scheduled* arrival — under overload the
queueing delay lands in TTFT, which is exactly what an open-loop
latency-vs-QPS curve must show.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro.serving.request import Phase, Request, SamplingParams


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------
class VirtualClock:
    """Deterministic clock double: ``sleep`` advances time instead of
    waiting, so a pump loop driven under it is a pure function of its
    inputs.  The interface matches the ``time`` module (``monotonic`` /
    ``sleep``), which is the default real clock."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def monotonic(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        self._t += max(0.0, float(dt))

    def advance(self, dt: float) -> None:
        self.sleep(dt)


# ---------------------------------------------------------------------------
# SLO admission control (pure functions of observable state)
# ---------------------------------------------------------------------------
ADMIT, QUEUE, SHED = "admit", "queue", "shed"


@dataclass(frozen=True)
class SLOConfig:
    """Admission-control knobs.  ``ttft_budget_s=inf`` disables control
    entirely (every request is admitted — the uncontrolled A/B)."""
    ttft_budget_s: float = float("inf")
    action: str = "shed"            # on projected breach: "shed" | "queue"
    max_queue: int = 64             # bounded front-end queue (queue mode)

    def __post_init__(self):
        if self.action not in (SHED, QUEUE):
            raise ValueError(
                f"SLOConfig.action must be 'shed' or 'queue', "
                f"got {self.action!r}")


@dataclass(frozen=True)
class AdmissionSnapshot:
    """Observable state the admission decision is a pure function of.
    Built by ``ServingFrontend._snapshot`` from the engine scheduler(s)
    and the front-end queue; tests construct it directly."""
    pending_prefill_tokens: int    # engine-side backlog (waiting +
    #                                mid-prefill remainders + preempted)
    queued_prefill_tokens: int     # front-end SLO queue backlog
    queue_len: int                 # front-end SLO queue length
    live_decodes: int              # running decode streams
    free_slots: int                # unoccupied decode slots
    est_step_s: float              # EWMA of measured engine step time


def projected_ttft_s(prompt_len: int, snap: AdmissionSnapshot,
                     max_step_tokens: int) -> float:
    """Projected TTFT for a new arrival: every queued prompt token ahead
    of it (engine backlog + front-end queue + its own prompt) must flow
    through the per-step prefill budget — which running decodes eat
    into — plus one decode step to emit the first token."""
    backlog = (snap.pending_prefill_tokens + snap.queued_prefill_tokens
               + prompt_len)
    per_step = max(1, max_step_tokens - snap.live_decodes)
    steps = backlog / per_step + 1.0
    return steps * snap.est_step_s


def admission_decision(prompt_len: int, snap: AdmissionSnapshot,
                       slo: SLOConfig, max_step_tokens: int) -> str:
    """ADMIT / QUEUE / SHED for one arrival — pure and deterministic.

    Invariants the property tests pin:
      * an infinite budget always admits (uncontrolled mode);
      * an **idle system never sheds** (no backlog, no queue, no live
        decodes): whatever the offered rate, the server always serves at
        least its sequential service rate — the rate floor;
      * QUEUE is only returned while ``queue_len < max_queue`` — the
        front-end queue is bounded by construction.
    """
    if slo.ttft_budget_s == float("inf"):
        return ADMIT
    idle = (snap.pending_prefill_tokens == 0 and snap.queue_len == 0
            and snap.live_decodes == 0)
    if idle:
        return ADMIT
    if projected_ttft_s(prompt_len, snap, max_step_tokens) \
            <= slo.ttft_budget_s:
        return ADMIT
    if slo.action == QUEUE and snap.queue_len < slo.max_queue:
        return QUEUE
    return SHED


# ---------------------------------------------------------------------------
# stream handles
# ---------------------------------------------------------------------------
@dataclass
class StreamHandle:
    """Caller-facing view of one submitted request.  Mutated only by
    the pump thread; terminal exactly once (``done`` or ``shed``)."""
    prompt: List[int]
    params: SamplingParams
    session_id: Optional[str]
    arrival_t: float               # front-end clock (scheduled arrival)
    on_token: Optional[Callable[[int, int], None]] = None
    on_done: Optional[Callable[["StreamHandle"], None]] = None
    submit_kw: dict = field(default_factory=dict)
    status: str = "pending"        # pending → queued → running → done
    #                                        ↘ shed (terminal)
    request: Optional[Request] = None
    tokens: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.arrival_t

    @property
    def tbts(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times,
                                      self.token_times[1:])]


def _percentile(vals: Sequence[float], p: float) -> float:
    vals = sorted(vals)
    if not vals:
        return 0.0
    return vals[min(len(vals) - 1, int(p * len(vals)))]


# ---------------------------------------------------------------------------
# the front-end
# ---------------------------------------------------------------------------
class ServingFrontend:
    """Thread-pumped serving loop over a ``ServingEngine`` or
    ``ReplicaCluster``.

    ``step_time_s``: when set, each engine step charges that fixed
    virtual cost to the clock (``clock.sleep``) instead of relying on
    wall time passing — with a ``VirtualClock`` this makes every
    latency metric deterministic.  Leave ``None`` under the real clock
    (step cost is then the measured wall time).
    """

    def __init__(self, engine, *, slo: Optional[SLOConfig] = None,
                 clock=time, step_time_s: Optional[float] = None,
                 idle_sleep_s: float = 1e-4,
                 est_step_s: float = 5e-3, ewma_alpha: float = 0.2):
        self.engine = engine
        self.slo = slo if slo is not None else SLOConfig()
        self.clock = clock
        self.step_time_s = step_time_s
        self.idle_sleep_s = idle_sleep_s
        self._est_step_s = est_step_s
        self._ewma_alpha = ewma_alpha
        self._lock = threading.Lock()
        self._inbox: Deque[StreamHandle] = deque()
        self._queue: Deque[StreamHandle] = deque()     # SLO queue
        self._active: Dict[int, StreamHandle] = {}     # request_id → handle
        self._handles: List[StreamHandle] = []         # every submission
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False
        # ledger: offered == admitted + shed + (inbox + queue still open)
        self.offered = 0
        self.admitted = 0
        self.shed = 0
        self.done = 0
        self.goodput = 0           # done with TTFT ≤ budget
        self.queued_peak = 0
        self.pump_iterations = 0
        self._ttfts: List[float] = []
        self._tbts: List[float] = []

    # -- engine abstraction (single engine or cluster) ----------------------
    def _engines(self) -> list:
        eng = self.engine
        if hasattr(eng, "engines"):            # ReplicaCluster
            return list(eng.engines.values())
        return [eng]

    @property
    def max_step_tokens(self) -> int:
        return self._engines()[0].ecfg.max_step_tokens

    def _engine_has_work(self) -> bool:
        if hasattr(self.engine, "has_work"):   # cluster
            return self.engine.has_work()
        return self.engine.scheduler.has_work()

    # -- submission ---------------------------------------------------------
    def submit(self, prompt: Sequence[int], *,
               params: Optional[SamplingParams] = None,
               session_id: Optional[str] = None,
               arrival_t: Optional[float] = None,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_done: Optional[Callable[[StreamHandle], None]] = None,
               **submit_kw) -> StreamHandle:
        """Thread-safe, non-blocking: enqueue an arrival for the pump.
        ``arrival_t`` defaults to now; the open-loop driver passes the
        *scheduled* arrival so queueing delay lands in TTFT."""
        if self._closed:
            raise RuntimeError("frontend is shut down")
        h = StreamHandle(
            prompt=list(prompt),
            params=params if params is not None else SamplingParams(),
            session_id=session_id,
            arrival_t=(self.clock.monotonic() if arrival_t is None
                       else arrival_t),
            on_token=on_token, on_done=on_done, submit_kw=dict(submit_kw))
        with self._lock:
            self.offered += 1
            self._inbox.append(h)
            self._handles.append(h)
        return h

    # -- admission ----------------------------------------------------------
    def _snapshot(self) -> AdmissionSnapshot:
        pend = live = free = 0
        for e in self._engines():
            sch = e.scheduler
            pend += sum(r.prompt_len for r in sch.waiting)
            pend += sum(r.prompt_len for r in sch.preempted)
            for r in sch.running.values():
                if r.phase is Phase.PREFILL:
                    pend += r.prefill_left
                elif r.phase is Phase.DECODE:
                    live += 1
            free += len(e.kv.free_slots())
        qtok = sum(len(h.prompt) for h in self._queue)
        return AdmissionSnapshot(
            pending_prefill_tokens=pend, queued_prefill_tokens=qtok,
            queue_len=len(self._queue), live_decodes=live,
            free_slots=free, est_step_s=self._est_step_s)

    def _engine_submit(self, h: StreamHandle) -> None:
        h.request = self.engine.submit(
            h.prompt, params=h.params, session_id=h.session_id,
            **h.submit_kw)
        h.status = "running"
        h.admit_t = self.clock.monotonic()
        self._active[h.request.request_id] = h
        self.admitted += 1

    def _terminal_shed(self, h: StreamHandle) -> None:
        h.status = "shed"
        h.done_t = self.clock.monotonic()
        self.shed += 1
        if h.on_done is not None:
            h.on_done(h)

    def _admit_arrival(self, h: StreamHandle) -> None:
        decision = admission_decision(len(h.prompt), self._snapshot(),
                                      self.slo, self.max_step_tokens)
        if decision == ADMIT:
            self._engine_submit(h)
        elif decision == QUEUE:
            h.status = "queued"
            self._queue.append(h)
            self.queued_peak = max(self.queued_peak, len(self._queue))
        else:
            self._terminal_shed(h)

    def _drain_queue(self) -> None:
        """Re-evaluate the SLO queue head as backlog drains: admit when
        its projection (head excluded from the queued backlog) fits; a
        head that has already waited past the budget can no longer make
        its SLO — shed it, so the queue's occupancy is bounded in time
        as well as length."""
        while self._queue:
            h = self._queue[0]
            if self.clock.monotonic() - h.arrival_t > self.slo.ttft_budget_s:
                self._queue.popleft()
                self._terminal_shed(h)
                continue
            snap = self._snapshot()
            snap = AdmissionSnapshot(
                pending_prefill_tokens=snap.pending_prefill_tokens,
                queued_prefill_tokens=(snap.queued_prefill_tokens
                                       - len(h.prompt)),
                queue_len=snap.queue_len - 1,
                live_decodes=snap.live_decodes,
                free_slots=snap.free_slots,
                est_step_s=snap.est_step_s)
            if projected_ttft_s(len(h.prompt), snap, self.max_step_tokens) \
                    <= self.slo.ttft_budget_s:
                self._queue.popleft()
                self._engine_submit(h)
            else:
                break

    # -- the pump -----------------------------------------------------------
    def _deliver(self) -> int:
        """Post-step delivery: new tokens → ``on_token`` (once each, in
        order), completions → ``on_done`` (terminal, once).  Handles are
        visited in request-id (submission) order for determinism."""
        now = self.clock.monotonic()
        delivered = 0
        for rid in sorted(self._active):
            h = self._active[rid]
            req = h.request
            new = req.generated[len(h.tokens):]
            for tok in new:
                idx = len(h.tokens)
                h.tokens.append(tok)
                h.token_times.append(now)
                if h.first_token_t is None:
                    h.first_token_t = now
                if h.on_token is not None:
                    h.on_token(tok, idx)
                delivered += 1
            if req.phase is Phase.DONE:
                self._active.pop(rid)
                h.status = "done"
                h.done_t = now
                self.done += 1
                ttft = h.ttft
                if ttft is not None:
                    self._ttfts.append(ttft)
                    if ttft <= self.slo.ttft_budget_s:
                        self.goodput += 1
                self._tbts.extend(h.tbts)
                if h.on_done is not None:
                    h.on_done(h)
        return delivered

    def pump_once(self) -> int:
        """One front-end iteration: drain the inbox through admission,
        re-evaluate the SLO queue, step the engine once (charging
        measured or fixed virtual time), deliver tokens/completions.
        Returns tokens delivered."""
        with self._lock:
            arrivals = list(self._inbox)
            self._inbox.clear()
        for h in arrivals:
            self._admit_arrival(h)
        self._drain_queue()
        stepped = False
        t0 = self.clock.monotonic()
        if self._engine_has_work():
            self.engine.step()
            stepped = True
            if self.step_time_s is not None:
                self.clock.sleep(self.step_time_s)
            dt = self.clock.monotonic() - t0
            if dt > 0:
                a = self._ewma_alpha
                self._est_step_s = (1 - a) * self._est_step_s + a * dt
        delivered = self._deliver()
        if not stepped:
            self.clock.sleep(self.idle_sleep_s)
        self.pump_iterations += 1
        return delivered

    # -- inline (deterministic) driving -------------------------------------
    def run_for(self, n_steps: Optional[int] = None,
                duration_s: Optional[float] = None) -> int:
        """Pump inline on the caller's thread — the deterministic mode
        the test suite drives (no background thread).  Bounded by
        ``n_steps`` pump iterations and/or ``duration_s`` on the
        front-end clock; returns iterations run."""
        if n_steps is None and duration_s is None:
            raise ValueError("pass n_steps and/or duration_s")
        t_end = (None if duration_s is None
                 else self.clock.monotonic() + duration_s)
        i = 0
        while (n_steps is None or i < n_steps) and \
                (t_end is None or self.clock.monotonic() < t_end):
            self.pump_once()
            i += 1
        return i

    def serve_schedule(self, arrivals, *, drain: bool = True,
                       on_token=None, on_done=None,
                       max_pumps: int = 2_000_000) -> List[StreamHandle]:
        """Open-loop driver: replay a ``traces/loadgen.py`` schedule
        against the front-end clock.  Each arrival submits once the
        clock passes its timestamp (with ``arrival_t`` pinned to the
        *scheduled* time, so catch-up delay lands in TTFT); with
        ``drain=True`` the loop pumps until every accepted request
        reaches a terminal state."""
        t0 = self.clock.monotonic()
        handles: List[StreamHandle] = []
        i, pumps = 0, 0
        while i < len(arrivals) or (drain and self.in_flight() > 0):
            now = self.clock.monotonic() - t0
            while i < len(arrivals) and arrivals[i].t <= now:
                a = arrivals[i]
                handles.append(self.submit(
                    list(a.prompt),
                    params=SamplingParams(max_new_tokens=a.max_new),
                    session_id=a.session_id,
                    arrival_t=t0 + a.t,
                    on_token=on_token, on_done=on_done,
                    block_types=list(a.block_types), tool=a.tool,
                    retain_blocks=not a.last_turn))
                i += 1
            if (not self._engine_has_work() and not self._queue
                    and not self._inbox and i < len(arrivals)):
                # idle gap: sleep the clock up to the next arrival
                gap = (t0 + arrivals[i].t) - self.clock.monotonic()
                if gap > 0:
                    self.clock.sleep(gap)
                continue
            self.pump_once()
            pumps += 1
            if pumps >= max_pumps:
                raise RuntimeError("serve_schedule did not converge "
                                   f"within {max_pumps} pump iterations")
        return handles

    # -- background thread --------------------------------------------------
    def start(self) -> None:
        """Launch the pump thread (real-clock serving)."""
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                self.pump_once()

        self._thread = threading.Thread(target=_loop,
                                        name="frontend-pump", daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Clean shutdown.  With ``drain=True`` (default) the pump keeps
        running until every accepted request is terminal — no request is
        leaked — then the thread exits and the engine(s) shut down.

        The drain is *bounded*: a request stuck behind a dead tier or a
        permanently stalled transfer cannot hold shutdown hostage.  At
        the drain deadline every still-open submission is cancelled in
        the engine (freeing its decode slot and KV blocks) and marked
        shed, so the ledger still balances (``offered == shed + done``)
        and ``check_ledger`` passes after a chaotic shutdown."""
        if self._thread is not None:
            if drain:
                deadline = time.monotonic() + timeout
                while self.in_flight() > 0:
                    if time.monotonic() >= deadline:
                        self._shed_stuck()
                        break
                    time.sleep(1e-3)
            self._stop.set()
            self._thread.join(timeout=timeout)
            self._thread = None
        elif drain:
            deadline = self.clock.monotonic() + timeout
            while self.in_flight() > 0:
                if self.clock.monotonic() >= deadline:
                    self._shed_stuck()
                    break
                self.pump_once()
        self._closed = True
        self.engine.shutdown()

    def _shed_stuck(self) -> None:
        """Drain-deadline escalation: cancel every open submission.

        Engine-resident requests are cancelled through
        ``cancel_request`` (slot released, KV blocks freed, tier copies
        dropped); inbox/queue entries never reached the engine and are
        shed directly.  Each open handle reaches its terminal state
        exactly once, preserving the ledger invariant."""
        with self._lock:
            pending = list(self._inbox) + list(self._queue)
            self._inbox.clear()
            self._queue.clear()
            stuck = [self._active.pop(rid) for rid in sorted(self._active)]
        for h in pending:
            self._terminal_shed(h)
        for h in stuck:
            if h.request is not None and hasattr(self.engine,
                                                "cancel_request"):
                self.engine.cancel_request(h.request)
            self._terminal_shed(h)

    # -- accounting ---------------------------------------------------------
    def in_flight(self) -> int:
        """Accepted-or-pending requests not yet terminal: inbox + SLO
        queue + engine-resident."""
        with self._lock:
            return len(self._inbox) + len(self._queue) + len(self._active)

    def check_ledger(self) -> None:
        """Every submission is in exactly one state; terminal states are
        reached exactly once.  The soak test calls this under load."""
        with self._lock:
            n_inbox, n_queue = len(self._inbox), len(self._queue)
            n_active = len(self._active)
            offered, shed, done = self.offered, self.shed, self.done
            n_handles = len(self._handles)
        assert offered == n_handles, (offered, n_handles)
        assert offered == shed + done + n_inbox + n_queue + n_active, (
            f"ledger leak: offered={offered} shed={shed} done={done} "
            f"inbox={n_inbox} queue={n_queue} active={n_active}")

    def stats(self) -> dict:
        with self._lock:
            in_flight = (len(self._inbox) + len(self._queue)
                         + len(self._active))
            out = {
                "offered": self.offered,
                "admitted": self.admitted,
                "shed": self.shed,
                "done": self.done,
                "goodput": self.goodput,
                "in_flight": in_flight,
                "queued_now": len(self._queue),
                "queued_peak": self.queued_peak,
                "pump_iterations": self.pump_iterations,
                "est_step_s": self._est_step_s,
                "ttft_budget_s": self.slo.ttft_budget_s,
                "ttft_p50": _percentile(self._ttfts, 0.50),
                "ttft_p99": _percentile(self._ttfts, 0.99),
                "tbt_p50": _percentile(self._tbts, 0.50),
                "tbt_p99": _percentile(self._tbts, 0.99),
                "generated_tokens": sum(len(h.tokens)
                                        for h in self._handles),
            }
        return out
