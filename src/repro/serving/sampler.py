"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serving.request import SamplingParams


def sample(logits: jax.Array, rng: jax.Array, *, temperature: float = 0.0,
           top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """logits [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jnp.sort(lg, axis=-1)[:, -top_k][:, None]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    if top_p < 1.0:
        sorted_lg = jnp.sort(lg, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_lg, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_lg, cutoff_idx[:, None],
                                     axis=-1)
        lg = jnp.where(lg < cutoff, -jnp.inf, lg)
    return jax.random.categorical(rng, lg, axis=-1).astype(jnp.int32)


def sample_batched(logits: jax.Array, rng: jax.Array,
                   temperature: jax.Array, top_k: jax.Array,
                   top_p: jax.Array) -> jax.Array:
    """Per-row sampling for the whole decode batch in one traced op:
    logits [B, V] + per-row ``temperature``/``top_k``/``top_p`` arrays
    [B] -> tokens [B].  This is the fused step closure's sampler — the
    unfused path issues one ``sample`` dispatch (plus one device sync)
    per request instead.

    Row semantics match ``sample``: temperature <= 0 is greedy (rng
    unused, so fused and unfused greedy decode are token-identical);
    top_k <= 0 and top_p >= 1 disable those filters.  Stochastic rows
    draw from ``jax.random.fold_in(rng, row)`` — a different key stream
    than the unfused path's sequential splits, same distribution.
    """
    B, V = logits.shape
    lg32 = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg32, axis=-1).astype(jnp.int32)
    # greedy rows divide by 1e-6 here and are overridden below; logits
    # are O(10) so the scaled values stay finite
    lg = lg32 / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: drop everything below the kth-largest (k = V disables)
    k = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V)
    kth = jnp.take_along_axis(jnp.sort(lg, axis=-1), (V - k)[:, None],
                              axis=-1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)
    # top-p: drop everything below the nucleus cutoff (p >= 1 keeps all
    # mass — the cutoff lands at the smallest kept value)
    sorted_lg = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_lg, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.minimum(jnp.sum(cum < top_p[:, None], axis=-1), V - 1)
    cutoff = jnp.take_along_axis(sorted_lg, cutoff_idx[:, None], axis=-1)
    lg = jnp.where(lg < cutoff, -jnp.inf, lg)
    keys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(jnp.arange(B))
    sampled = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def make_sampler(params: SamplingParams):
    def f(logits, rng):
        return sample(logits, rng, temperature=params.temperature,
                      top_k=params.top_k, top_p=params.top_p)
    return f
