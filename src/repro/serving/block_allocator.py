"""Refcounted fixed-size page allocator for the paged KV cache.

The global KV pool is a flat array of physical pages (page = a fixed
number of token positions, all layers stacked alongside in the pool
tensors).  Each page carries a reference count:

  * a decode slot holds one reference per page in its block table;
  * the PredictiveCacheManager holds one reference per page backing a
    registered (tier-0-resident) prompt block;
  * radix-prefix hits map the *same* physical pages into a new slot's
    block table (refcount bump — copy-on-write sharing, §III-F).

Pages return to the free list only when the count reaches zero, so a
finished request's prefix pages survive for cross-request reuse exactly
as long as the cache manager keeps the block hot.  Writers must call
``ensure_private`` (via PagedKVCache) before mutating a shared page —
the copy-on-write step.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Sequence

from repro.core.tiers import CapacityError

RESERVED = -1          # refcount sentinel: page never allocatable


@dataclass
class AllocatorStats:
    allocated: int = 0        # pages handed out
    freed: int = 0            # pages returned to the free list
    shares: int = 0           # CoW references added (prefix sharing)
    cow_copies: int = 0       # private copies forced by a write to a shared page
    peak_in_use: int = 0

    def as_dict(self) -> dict:
        return {"allocated": self.allocated, "freed": self.freed,
                "shares": self.shares, "cow_copies": self.cow_copies,
                "peak_in_use": self.peak_in_use}


class BlockAllocator:
    """Free-list page allocator with per-page refcounts."""

    def __init__(self, n_pages: int, reserved: Sequence[int] = ()):
        self.n_pages = n_pages
        self._refs = [0] * n_pages
        rset = set(reserved)
        for r in rset:
            self._refs[r] = RESERVED
        self._free: Deque[int] = deque(i for i in range(n_pages)
                                       if i not in rset)
        self._lock = threading.Lock()
        self._in_use = 0           # incrementally tracked page count —
        #                            alloc() used to recount every
        #                            refcount per call, an O(n_pages)
        #                            scan on the per-step decode path
        self.stats = AllocatorStats()

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def in_use(self) -> int:
        with self._lock:
            return self._in_use

    def refcount(self, page_id: int) -> int:
        return self._refs[page_id]

    # ------------------------------------------------------------------
    def alloc(self, n: int = 1) -> List[int]:
        """Take ``n`` pages off the free list (refcount 1 each)."""
        with self._lock:
            if len(self._free) < n:
                raise CapacityError(
                    f"KV pool exhausted: need {n} pages, "
                    f"{len(self._free)}/{self.n_pages} free")
            out = [self._free.popleft() for _ in range(n)]
            for pid in out:
                self._refs[pid] = 1
            self.stats.allocated += n
            self._in_use += n
            self.stats.peak_in_use = max(self.stats.peak_in_use,
                                         self._in_use)
            return out

    def ref(self, page_id: int, *, share: bool = False) -> None:
        """Add a reference to an already-allocated page."""
        with self._lock:
            if self._refs[page_id] <= 0:
                raise ValueError(f"page {page_id} not allocated")
            self._refs[page_id] += 1
            if share:
                self.stats.shares += 1

    def deref(self, page_id: int) -> bool:
        """Drop one reference; returns True if the page was freed."""
        with self._lock:
            r = self._refs[page_id]
            if r == RESERVED:
                return False
            if r <= 0:
                raise ValueError(f"page {page_id} double-free")
            self._refs[page_id] = r - 1
            if r == 1:
                self._free.append(page_id)
                self.stats.freed += 1
                self._in_use -= 1
                return True
            return False

    def note_cow_copy(self) -> None:
        with self._lock:
            self.stats.cow_copies += 1

    # ------------------------------------------------------------------
    def stats_dict(self) -> dict:
        with self._lock:
            d = self.stats.as_dict()
        d.update(n_pages=self.n_pages, free=self.n_free, in_use=self.in_use)
        return d
