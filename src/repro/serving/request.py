"""Request / session types for the serving engine."""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import List, Optional, Sequence

_ids = itertools.count()


class Phase(Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    PREEMPTED = "preempted"
    RESTORING = "restoring"        # KV fetch from a lower tier in flight
    DONE = "done"


@dataclass
class SamplingParams:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 -> greedy
    top_k: int = 0
    top_p: float = 1.0
    stop_token: Optional[int] = None


@dataclass
class Request:
    prompt: Sequence[int]
    params: SamplingParams = field(default_factory=SamplingParams)
    session_id: Optional[str] = None
    block_type: str = "user_context"   # semantic role of the prompt blocks
    block_types: Optional[List[str]] = None   # per-block roles (index =
    #                                    prompt block number; overrides
    #                                    block_type where present)
    tool: Optional[str] = None         # agentic workloads: invoked tool
    retain_blocks: bool = False        # keep prompt blocks registered after
    #                                    finish (session continuation: the
    #                                    next turn resubmits this prefix)
    request_id: int = field(default_factory=lambda: next(_ids))
    arrival: float = field(default_factory=time.monotonic)

    # runtime state
    phase: Phase = Phase.WAITING
    phase_start: float = field(default_factory=time.monotonic)
    generated: List[int] = field(default_factory=list)
    slot: int = -1                     # decode batch slot
    block_ids: List[str] = field(default_factory=list)
    prefix_hit_blocks: int = 0         # radix-matched blocks (skipped prefill)
    hot_hit_blocks: int = 0            # ... of those, resident in tiers 0-1
    #                                    at access time (paper Table V hit)
    shared_hit_blocks: int = 0         # blocks imported from the fleet-shared
    #                                    tier (another replica's content; a
    #                                    tier-4 fetch, NOT a hot hit)
    segment_hit_blocks: int = 0        # blocks resumed mid-prompt via the
    #                                    content-segment index (beyond the
    #                                    contiguous radix prefix)
    seg_spans: List[tuple] = field(default_factory=list)
    #                                  # resumed (start_block, n_blocks)
    #                                    spans, ascending, for the gap-wise
    #                                    segment prefill path
    # chunked prefill: tokens to prefill (prompt [+ generated] minus the
    # final token) and the per-request chunk cursor into them
    prefill_tokens: Optional[List[int]] = None
    prefill_pos: int = 0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def prefill_left(self) -> int:
        """Prompt tokens still to prefill (0 outside the chunked path)."""
        if self.prefill_tokens is None:
            return 0
        return max(0, len(self.prefill_tokens) - self.prefill_pos)

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    def reset_for_redispatch(self) -> None:
        """Wipe per-engine runtime state before re-dispatching to a
        different replica (failover): the slot, block ids, chunk cursor
        and prefix/hot hit accounting all referred to the dead engine's
        pool and cache manager, and the generated tokens' KV died with
        it — the successor re-prefills the prompt from scratch."""
        self.phase = Phase.WAITING
        self.phase_start = time.monotonic()
        self.generated.clear()
        self.slot = -1
        self.block_ids = []
        self.prefix_hit_blocks = 0
        self.hot_hit_blocks = 0
        self.shared_hit_blocks = 0
        self.segment_hit_blocks = 0
        self.seg_spans = []
        self.prefill_tokens = None
        self.prefill_pos = 0
        self.t_first_token = None
        self.t_done = None

    def finished(self) -> bool:
        p = self.params
        if len(self.generated) >= p.max_new_tokens:
            return True
        return (p.stop_token is not None and self.generated
                and self.generated[-1] == p.stop_token)
