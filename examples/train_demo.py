"""Training driver: a small llama-family model on the synthetic pipeline
with delta-encoded checkpoint/restart (kill it mid-run and re-launch —
it resumes bit-exactly).

    PYTHONPATH=src python examples/train_demo.py [--steps 300]
"""
import argparse

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_demo")
    args = ap.parse_args()
    train_launch.main([
        "--arch", "llama3.2-1b", "--reduced",
        "--steps", str(args.steps),
        "--seq-len", "64", "--global-batch", "4",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
