"""Quickstart: the paper's sizing engine + a tiny model served end-to-end.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.config import reduce_config
from repro.configs import get_config
from repro.configs.paper_models import PAPER_MODELS
from repro.core import sizing
from repro.serving import EngineConfig, SamplingParams, ServingEngine


def main():
    # 1. Architecture-variant-aware sizing (paper §III-A) -----------------
    print("=== KV cache sizing across attention variants (Table I/III) ===")
    for name, cfg in PAPER_MODELS.items():
        r = sizing.sizing_report(cfg)
        print(f"{name:16s} {r.variant:4s} {r.per_token_layer:7.0f} B/tok/layer"
              f"  (MHA-equivalent {r.mha_equivalent:6.0f} B, "
              f"{r.compression:5.1f}x)  batch {r.max_batch_status_quo:4d}"
              f" -> {r.max_batch_arch_aware:4d}")

    # 2. Serve a tiny llama with the predictive multi-tier cache ---------
    print("\n=== Serving with predictive multi-tier KV cache ===")
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=256,
                                          kv_budget_bytes=16e6))
    print(f"decode slots (sizing-engine admission): {eng.scheduler.n_slots}"
          f", block = {eng.manager.block_tokens} tokens")
    rng = np.random.default_rng(0)
    system_prompt = [int(t) for t in rng.integers(0, 200, size=128)]
    for i in range(4):
        user = [int(t) for t in rng.integers(0, 200, size=16)]
        eng.submit(system_prompt + user,
                   params=SamplingParams(max_new_tokens=8),
                   session_id=f"user{i}", block_type="system_prompt")
    stats = eng.run()
    s, c = stats["scheduler"], stats["cache"]
    print(f"served {s['done']} requests; prefix-hit blocks "
          f"{s['prefix_hit_blocks']} (system prompt reused, prefill "
          f"skipped); dedup hits {c['dedup']['dedup_hits']}; "
          f"hot hit-rate {c['hit_rate_hot']:.0%}")


if __name__ == "__main__":
    main()
