"""Trace-driven end-to-end serving replay (paper §V-E at the engine).

Replays a slice of the synthetic agentic workload through the live
``ServingEngine`` — paged KV pool, CoW prefix sharing, chunked prefill,
async tier transfers — under a virtual clock, and compares the Bayesian
eviction policy against LRU on the same trace: engine-level tier-0/1
hit rate, TTFT/TBT percentiles and virtual throughput.

    PYTHONPATH=src python examples/trace_replay_serving.py

Full three-workload sweep: PYTHONPATH=src python -m benchmarks.run
--table replay (see docs/EVALUATION.md).
"""
from repro.traces.serving_replay import (ServingReplayConfig,
                                         run_serving_replay)


def main():
    print("agentic trace -> live engine, bayesian vs lru "
          "(~1-2 min on CPU)\n")
    results = []
    for policy in ("bayesian", "lru"):
        # tier capacities sized for pressure at this reduced trace scale
        # (the full-scale defaults live in ENGINE_REPLAY_BLOCKS)
        r = run_serving_replay(ServingReplayConfig(
            workload="agentic", policy=policy, n_sessions=8, max_turns=5,
            hot_blocks=40, t1_blocks=56))
        results.append(r)
        print(f"[{policy}]")
        print(f"  engine hit rate (tiers 0-1): {100 * r.engine_hit_rate:.1f}%"
              f"  (served from cache at any tier: {100 * r.reuse_rate:.1f}%)")
        print(f"  hit source: pool/CoW {r.cow_share_hits}, "
              f"tier payload inject {r.inject_hits} "
              f"(t0 {r.hot_hits_t0} / t1 {r.hot_hits_t1})")
        print(f"  promotions {r.promotions}, demotions {r.demotions}")
        print(f"  TTFT p50/p95: {1e3 * r.ttft_p50:.1f} / "
              f"{1e3 * r.ttft_p95:.1f} ms (virtual)")
        print(f"  TBT p50/p95:  {1e3 * r.tbt_p50:.1f} / "
              f"{1e3 * r.tbt_p95:.1f} ms (virtual)")
        print(f"  throughput: {r.throughput_tok_s:.0f} tok/s (virtual), "
              f"{r.requests_done} turns, wall {r.wall_s:.0f}s\n")
    bay, lru = results
    print(f"bayesian - lru hit-rate gap: "
          f"{100 * (bay.engine_hit_rate - lru.engine_hit_rate):+.1f} pts")


if __name__ == "__main__":
    main()
