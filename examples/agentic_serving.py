"""Agentic workload demo (paper §III-G): tool-calling sessions drive the
Markov transition predictor; tool contexts are reused across sessions via
the content-addressed store.

    PYTHONPATH=src python examples/agentic_serving.py
"""
import numpy as np

from repro.config import reduce_config
from repro.configs import get_config
from repro.core.agentic import classify_session, SessionFeatures
from repro.serving import EngineConfig, SamplingParams, ServingEngine

TOOLS = ["search", "fetch", "calc"]


def main():
    cfg = reduce_config(get_config("llama3.2-1b"))
    eng = ServingEngine(cfg, EngineConfig(max_len=512,
                                          kv_budget_bytes=32e6))
    rng = np.random.default_rng(2)
    agent_sys = [int(t) for t in rng.integers(0, 200, size=128)]
    tool_ctx = {t: [int(x) for x in rng.integers(0, 200, size=128)]
                for t in TOOLS}
    # ReAct-ish: search -> fetch -> calc, repeated across 3 sessions
    for s in range(3):
        for step, tool in enumerate(["search", "fetch", "fetch", "calc"]):
            scratch = [int(x) for x in rng.integers(0, 200, size=16)]
            eng.submit(agent_sys + tool_ctx[tool] + scratch,
                       params=SamplingParams(max_new_tokens=4),
                       session_id=f"agent{s}", block_type="tool_context",
                       tool=tool)
    eng.run()
    mk = eng.manager.agentic
    print("learned tool-transition matrix P(next | tool):")
    for t in TOOLS:
        probs = mk.transition_probs(t)
        row = "  ".join(f"{k}={v:.2f}" for k, v in sorted(probs.items()))
        print(f"  {t:7s} -> {row}")
    print("predicted next after 'search':", mk.predict_next("search", 1))
    print("pre-allocation target (bytes):",
          f"{mk.predicted_memory_demand('search'):.0f}")
    f = SessionFeatures(total_tokens=12_000, n_tool_calls=12,
                        distinct_tools=3, peak_kv_bytes=3 * 1024 ** 3)
    print("session class:", classify_session(f))
    st = eng.stats()
    print("prefix-hit blocks (tool ctx reused):",
          st["scheduler"]["prefix_hit_blocks"])


if __name__ == "__main__":
    main()
