"""End-to-end serving driver: batched requests through the full
predictive multi-tier stack, with per-tier stats, preemption and a
replica-failure drill.

    PYTHONPATH=src python examples/serve_multi_tier.py
"""
import numpy as np

from repro.config import reduce_config
from repro.configs import get_config
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.cluster import ReplicaCluster


def main():
    cfg = reduce_config(get_config("llama3.2-1b"))
    ecfg = EngineConfig(max_len=256, kv_budget_bytes=8e6,
                        policy="bayesian")
    eng = ServingEngine(cfg, ecfg)
    rng = np.random.default_rng(1)
    templates = [[int(t) for t in rng.integers(0, 200, size=128)]
                 for _ in range(3)]
    reqs = []
    for i in range(12):
        tpl = templates[i % 3]
        user = [int(t) for t in rng.integers(0, 200, size=24)]
        reqs.append(eng.submit(tpl + user,
                               params=SamplingParams(max_new_tokens=6),
                               session_id=f"s{i}",
                               block_type="system_prompt"))
    stats = eng.run()
    print("=== single engine (paged block-table KV) ===")
    print("done:", stats["scheduler"]["done"],
          " prefix-hit blocks:", stats["scheduler"]["prefix_hit_blocks"])
    if stats.get("allocator"):
        al = stats["allocator"]
        print(f"page pool: {al['n_pages']} pages, peak {al['peak_in_use']} "
              f"in use, {al['shares']} CoW shares, "
              f"{al['cow_copies']} CoW copies")
    if stats.get("async_transfers"):
        aw = stats["async_transfers"]
        print(f"async transfers: {aw['completed']} completed off the step "
              f"loop ({aw['sim_time_total']:.2e}s modelled), "
              f"{aw['failed']} failed")
    for t in stats["cache"]["tiers"][:3]:
        print(f"  tier {t['tier']:10s} used {t['used'] / 1e6:6.2f} MB  "
              f"reads {t['reads']:4d}  writes {t['writes']:4d}  "
              f"evictions {t['evictions']}")
    print("predictor posteriors (observed pairs):")
    for k, v in stats["cache"]["predictor"].items():
        if v["obs"] > 0:
            print(f"  {k:45s} P={v['mean']:.2f} obs={v['obs']:.0f}")
    eng.shutdown()

    print("\n=== 2-replica cluster with failure drill ===")
    cluster = ReplicaCluster(cfg, ecfg, n_replicas=2)
    for i in range(8):
        user = [int(t) for t in rng.integers(0, 200, size=24)]
        cluster.submit(templates[0] + user, session_id=f"c{i % 4}",
                       params=SamplingParams(max_new_tokens=4),
                       block_type="system_prompt")
    for e in cluster.engines.values():
        e.step()
    victim = sorted(cluster.engines)[0]
    lost = cluster.fail_replica(victim)
    print(f"killed {victim}: re-dispatched {lost} in-flight requests, "
          f"{cluster.reprefill_tokens} tokens to re-prefill")
    agg = cluster.run()
    print("all completed:", agg["done"],
          f" fleet hot hit-rate: {agg['fleet']['hit_rate_hot']:.2%}")
    cluster.shutdown()


if __name__ == "__main__":
    main()
