#!/usr/bin/env python3
"""Docs link check: every repo-relative path referenced from README.md /
docs/*.md (markdown links and backticked paths) must exist.

    python scripts/check_doc_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#]+)(#[^)]*)?\)")
CODE_PATH = re.compile(r"`((?:src|docs|tests|examples|benchmarks|scripts)"
                       r"/[A-Za-z0-9_\-./]+)`")


def check(doc: Path) -> list:
    errors = []
    text = doc.read_text()
    refs = set()
    for m in MD_LINK.finditer(text):
        target = m.group(1).strip()
        if "://" in target or target.startswith("mailto:"):
            continue
        refs.add(target)
    for m in CODE_PATH.finditer(text):
        refs.add(m.group(1))
    for ref in sorted(refs):
        path = (doc.parent / ref).resolve()
        if not path.exists():
            # also try repo-root-relative (docs/ pages use both)
            if not (ROOT / ref).resolve().exists():
                errors.append(f"{doc.relative_to(ROOT)}: broken link {ref}")
    return errors


def main() -> int:
    errors = []
    for doc in DOCS:
        if doc.exists():
            errors.extend(check(doc))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(DOCS)} docs: "
          f"{'FAIL' if errors else 'OK'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
