#!/usr/bin/env python3
"""CI smoke for the trace→engine serving replay: a tiny agentic trace
(2 sessions x 2 turns) through the live ServingEngine, asserting the
harness completes and produces sane accounting — then the same trace
through a 2-replica ReplicaCluster with a mid-replay failover,
asserting every turn still completes and the redispatch/re-prefill
accounting is consistent.

    PYTHONPATH=src python scripts/replay_smoke.py
"""
from repro.traces.serving_replay import (ClusterReplayConfig,
                                         ServingReplayConfig,
                                         run_cluster_replay,
                                         run_serving_replay)


def single_engine_smoke() -> None:
    r = run_serving_replay(ServingReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2, max_turns=2,
        max_steps=500))
    assert r.requests_done > 0, "no turns completed"
    assert r.generated_tokens > 0, "no tokens generated"
    assert 0.0 <= r.engine_hit_rate <= 1.0
    assert r.engine_hit_rate <= r.reuse_rate
    assert r.virtual_time_s > 0.0
    print(f"replay smoke ok: {r.requests_done} turns, "
          f"hit {100 * r.engine_hit_rate:.1f}%, "
          f"reuse {100 * r.reuse_rate:.1f}%, "
          f"wall {r.wall_s:.1f}s")


def cluster_smoke() -> None:
    """2 replicas x 2 sessions, round-robin (both replicas guaranteed
    traffic), one replica killed after the first completed turn — the
    failover path must redispatch and still finish every turn."""
    r = run_cluster_replay(ClusterReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2, max_turns=2,
        n_replicas=2, routing="round_robin", fail_replica_after_turns=1,
        max_steps=500))
    assert r.requests_done == 4, f"expected 4 turns, got {r.requests_done}"
    assert len(r.failed_replicas) == 1
    assert r.redispatched >= 0 and r.reprefill_tokens >= 0
    assert (r.redispatched == 0) == (r.reprefill_tokens == 0)
    assert 0.0 <= r.fleet_hit_rate <= r.fleet_reuse_rate <= 1.0
    assert sum(p.requests_done for p in r.per_replica) == r.requests_done
    assert r.virtual_time_s > 0.0
    print(f"cluster smoke ok: {r.requests_done} turns on "
          f"{r.n_replicas} replicas ({len(r.failed_replicas)} failed), "
          f"fleet hit {100 * r.fleet_hit_rate:.1f}%, "
          f"redispatched {r.redispatched}, "
          f"re-prefilled {r.reprefill_tokens} tokens, "
          f"wall {r.wall_s:.1f}s")


def main() -> None:
    single_engine_smoke()
    cluster_smoke()


if __name__ == "__main__":
    main()
