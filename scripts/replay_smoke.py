#!/usr/bin/env python3
"""CI smoke for the trace→engine serving replay: a tiny agentic trace
(2 sessions x 2 turns) through the live ServingEngine, asserting the
harness completes and produces sane accounting — then the same trace
through a 2-replica ReplicaCluster with a mid-replay failover,
asserting every turn still completes and the redispatch/re-prefill
accounting is consistent — then once more with the fleet-shared tier 4
bound, asserting cross-replica imports actually happen — then a smoke
run of the fused step-loop microbench, whose host-overhead/kernel-time
ratio lands in the summary line — then a few seconds of *real-clock*
serving through the thread-pumped ``ServingFrontend`` at low open-loop
QPS, asserting goodput == offered and surfacing the measured p99 TTFT —
and finally a fault-injected chaos replay (seeded transient I/O errors
plus payload corruption on tiers 1-5) asserting zero hung requests, at
least one absorbed retry, and every injected corruption caught by its
crc32 check before decode.

The smoke also enforces a wall-clock budget (``REPLAY_SMOKE_BUDGET_S``,
0/unset disables): under the compiled ``xla`` kernel backend the whole
script is a few times faster than the old interpret-mode path, and the
budget catches a silent fall-back to the interpreter (or any comparable
wall-clock regression) in CI.  A ``smoke summary`` line with the
resolved backend and per-phase timings is printed for the job log.

    PYTHONPATH=src python scripts/replay_smoke.py
"""
import os
import time

from repro.kernels.backend import default_backend
from repro.traces.serving_replay import (ClusterReplayConfig,
                                         ServingReplayConfig, build_engine,
                                         run_cluster_replay,
                                         run_serving_replay)


def single_engine_smoke() -> None:
    r = run_serving_replay(ServingReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2, max_turns=2,
        max_steps=500))
    assert r.requests_done > 0, "no turns completed"
    assert r.generated_tokens > 0, "no tokens generated"
    assert 0.0 <= r.engine_hit_rate <= 1.0
    assert r.engine_hit_rate <= r.reuse_rate
    assert r.virtual_time_s > 0.0
    print(f"replay smoke ok: {r.requests_done} turns, "
          f"hit {100 * r.engine_hit_rate:.1f}%, "
          f"reuse {100 * r.reuse_rate:.1f}%, "
          f"wall {r.wall_s:.1f}s")


def segment_smoke() -> int:
    """Segment-granular prefix reuse through the live engine: two
    ShareGPT-shaped sessions whose prompts diverge mid-prompt — the
    second rewrites block 0 (the history-truncation shape: surviving
    turn blocks shifted to new positions) but keeps blocks 1..3 — must
    resume at least one mid-prompt segment past the divergence (CoW
    share or tier fetch), which the radix prefix cannot see at all."""
    import numpy as np
    from repro.serving.request import SamplingParams
    eng = build_engine(ServingReplayConfig(
        workload="sharegpt", policy="bayesian", n_sessions=2,
        async_transfers=False))
    bt = eng.manager.block_tokens
    rng = np.random.default_rng(0)
    blocks = [[int(t) for t in rng.integers(0, 200, size=bt)]
              for _ in range(4)]
    tail = [int(t) for t in rng.integers(0, 200, size=5)]
    r1 = eng.submit(sum(blocks, []) + tail,
                    params=SamplingParams(max_new_tokens=2),
                    session_id="seg-a", retain_blocks=True)
    eng.run(max_steps=500)
    assert r1.generated, "first session produced no tokens"
    divergent = [int(t) for t in rng.integers(200, 400, size=bt)]
    r2 = eng.submit(divergent + sum(blocks[1:], []) + tail,
                    params=SamplingParams(max_new_tokens=2),
                    session_id="seg-b")
    eng.run(max_steps=500)
    st = eng.stats()
    eng.shutdown()
    assert r2.generated, "divergent session produced no tokens"
    assert r2.prefix_hit_blocks == 0           # radix sees nothing
    assert r2.segment_hit_blocks >= 1, "no resumed-segment hits"
    resumed = st["segment_share_hits"] + st["segment_inject_hits"]
    assert resumed >= 1
    print(f"segment smoke ok: {r2.segment_hit_blocks} mid-prompt blocks "
          f"resumed past the divergence ({st['segment_share_hits']} "
          f"CoW-shared, {st['segment_inject_hits']} injected), "
          f"radix prefix hits {r2.prefix_hit_blocks}")
    return resumed


def cluster_smoke() -> None:
    """2 replicas x 2 sessions, round-robin (both replicas guaranteed
    traffic), one replica killed after the first completed turn — the
    failover path must redispatch and still finish every turn."""
    r = run_cluster_replay(ClusterReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2, max_turns=2,
        n_replicas=2, routing="round_robin", fail_replica_after_turns=1,
        max_steps=500))
    assert r.requests_done == 4, f"expected 4 turns, got {r.requests_done}"
    assert len(r.failed_replicas) == 1
    assert r.redispatched >= 0 and r.reprefill_tokens >= 0
    assert (r.redispatched == 0) == (r.reprefill_tokens == 0)
    assert 0.0 <= r.fleet_hit_rate <= r.fleet_reuse_rate <= 1.0
    assert sum(p.requests_done for p in r.per_replica) == r.requests_done
    assert r.virtual_time_s > 0.0
    print(f"cluster smoke ok: {r.requests_done} turns on "
          f"{r.n_replicas} replicas ({len(r.failed_replicas)} failed), "
          f"fleet hit {100 * r.fleet_hit_rate:.1f}%, "
          f"redispatched {r.redispatched}, "
          f"re-prefilled {r.reprefill_tokens} tokens, "
          f"wall {r.wall_s:.1f}s")


def shared_tier_smoke() -> None:
    """2 replicas with the fleet-shared tier 4 bound, session-blind
    routing: the trace's cross-session sharing must surface as at least
    one cross-replica tier-4 import, counted on top of the hot rate.
    3 sessions (odd) so round-robin genuinely alternates a session's
    turns across replicas — with 2 sessions on 2 replicas the parity
    makes round-robin accidentally session-affine."""
    r = run_cluster_replay(ClusterReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=3, max_turns=2,
        n_replicas=2, routing="round_robin", shared_tier=True,
        max_steps=500))
    assert r.requests_done == 6, f"expected 6 turns, got {r.requests_done}"
    assert r.shared_tier
    assert r.shared_hit_blocks > 0, "no cross-replica shared-tier imports"
    assert r.fleet_hit_rate_incl_shared >= r.fleet_hit_rate
    assert r.shared_hit_rate <= r.fleet_hit_rate_incl_shared <= 1.0
    print(f"shared-tier smoke ok: {r.requests_done} turns, "
          f"hot {100 * r.fleet_hit_rate:.1f}%, "
          f"incl-shared {100 * r.fleet_hit_rate_incl_shared:.1f}%, "
          f"{r.shared_hit_blocks} imported blocks, "
          f"wall {r.wall_s:.1f}s")


def steploop_smoke() -> float:
    """``--table steploop`` in smoke scale (one small fused run): the
    step loop must complete and its host-overhead/kernel-time ratio is
    surfaced in the summary line, so a host-side bookkeeping regression
    is visible in every CI log (the full batch-16 acceptance gate runs
    in ``benchmarks/run.py --table steploop``)."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parent.parent)
    if root not in sys.path:       # scripts/ is sys.path[0] when run
        sys.path.insert(0, root)   # directly; benchmarks/ lives at root
    from benchmarks.steploop_bench import bench_steploop
    r = bench_steploop(batch=8, fused=True, steps=10, warmup=3)
    assert r.step_ms > 0 and r.kernel_ms > 0
    assert r.recompiles["fused_decode"] <= 1, (
        f"fused step closure compiled {r.recompiles['fused_decode']} "
        f"variants in steady state")
    print(f"steploop smoke ok: b{r.batch} fused step {r.step_ms:.2f}ms "
          f"(kernel {r.kernel_ms:.2f}ms, host {r.host_ms:.2f}ms, "
          f"ratio {r.ratio:.2f})")
    return r.ratio


def frontend_smoke() -> float:
    """A few seconds of *real-clock* serving through the thread-pumped
    ``ServingFrontend`` at a low Poisson rate: no admission pressure, so
    goodput must equal offered (nothing shed, nothing leaked), and the
    measured p99 TTFT lands in the summary line."""
    from repro.serving.frontend import ServingFrontend
    from repro.serving.request import SamplingParams
    from repro.traces.loadgen import trace_load
    from repro.traces.serving_replay import ServingReplayConfig, build_engine

    fe = ServingFrontend(build_engine(ServingReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2,
        async_transfers=False)))
    arrivals = trace_load("agentic", 6.0, duration_s=2.0, seed=0,
                          n_sessions=2, max_turns=2)
    # warm up compilation inline (arrival-shaped prompts, concurrent so
    # batched decode variants compile too) so the timed phase measures
    # serving, not jit
    n_warm = 2
    for k in range(n_warm):
        fe.submit([k + 1] * len(arrivals[k].prompt),
                  params=SamplingParams(max_new_tokens=2))
    while fe.in_flight() > 0:
        fe.pump_once()
    fe.start()
    t0 = time.monotonic()
    for a in arrivals:
        dt = (t0 + a.t) - time.monotonic()
        if dt > 0:
            time.sleep(dt)
        fe.submit(list(a.prompt),
                  params=SamplingParams(max_new_tokens=a.max_new),
                  session_id=a.session_id, arrival_t=t0 + a.t,
                  block_types=list(a.block_types), tool=a.tool,
                  retain_blocks=not a.last_turn)
    fe.stop(drain=True, timeout=60.0)
    fe.check_ledger()
    st = fe.stats()
    offered = len(arrivals) + n_warm       # + the warm-up requests
    assert st["offered"] == offered
    assert st["shed"] == 0 and st["in_flight"] == 0
    assert st["goodput"] == st["offered"], (
        f"goodput {st['goodput']} != offered {st['offered']} "
        f"(shed {st['shed']}, done {st['done']})")
    print(f"frontend smoke ok: {st['done']} served at real clock, "
          f"ttft p99 {st['ttft_p99'] * 1e3:.0f}ms, "
          f"tbt p99 {st['tbt_p99'] * 1e3:.1f}ms")
    return st["ttft_p99"]


def chaos_smoke() -> tuple:
    """Fault-injected replay (``core/faults.py``): one session under
    tier pressure (tiny tier-0/1 capacities force demote/promote traffic
    through the faulted tiers) with seeded transient read errors and
    payload corruptions on tiers 1-5.  Every turn must still complete
    (errors retry, corrupt payloads convert to recompute — nothing
    hangs), with at least one retry absorbed and every injected
    corruption caught by its crc32 check before decode."""
    from repro.core.faults import FaultProfile
    prof = {t: FaultProfile(read_error_rate=0.25, write_error_rate=0.1,
                            corruption_rate=0.2) for t in (1, 2, 3, 4, 5)}
    r = run_serving_replay(ServingReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=1, max_turns=3,
        max_steps=2000, async_transfers=False, hot_blocks=4, t1_blocks=8,
        fault_profiles=prof, fault_seed=3))
    hung = r.turns_submitted - r.requests_done
    corruptions = r.injected.get("injected_corruptions", 0)
    assert hung == 0, f"{hung} requests hung under faults"
    assert r.retries >= 1, "no transient fault was retried"
    assert corruptions >= 1, "no corruption was injected"
    assert r.integrity_failures == corruptions, (
        f"{corruptions} corruptions injected, "
        f"{r.integrity_failures} caught")
    print(f"chaos smoke ok: {r.requests_done}/{r.turns_submitted} turns "
          f"under faults (0 hung), {r.retries} retries, "
          f"{r.io_errors} escalations, "
          f"{r.integrity_failures}/{corruptions} corruptions caught, "
          f"{r.fetch_recomputes} fetch recomputes, wall {r.wall_s:.1f}s")
    return r.retries, r.integrity_failures


def main() -> None:
    budget_s = float(os.environ.get("REPLAY_SMOKE_BUDGET_S", "0"))
    t0 = time.perf_counter()
    single_engine_smoke()
    t_single = time.perf_counter() - t0
    t_seg0 = time.perf_counter()
    segment_resumed = segment_smoke()
    t_segment = time.perf_counter() - t_seg0
    t1 = time.perf_counter()
    cluster_smoke()
    t_cluster = time.perf_counter() - t1
    t2 = time.perf_counter()
    shared_tier_smoke()
    t_shared = time.perf_counter() - t2
    t3 = time.perf_counter()
    steploop_ratio = steploop_smoke()
    t_steploop = time.perf_counter() - t3
    t4 = time.perf_counter()
    frontend_p99 = frontend_smoke()
    t_frontend = time.perf_counter() - t4
    t5 = time.perf_counter()
    chaos_retries, chaos_integrity = chaos_smoke()
    t_chaos = time.perf_counter() - t5
    elapsed = time.perf_counter() - t0
    # the tier-1 pytest step exports its wall time (TIER1_WALL_S) so the
    # job log carries one consolidated timing line
    tier1_s = os.environ.get("TIER1_WALL_S", "")
    print(f"smoke summary: kernel_backend={default_backend()} "
          f"single={t_single:.1f}s "
          f"segment={t_segment:.1f}s "
          f"segment_resumed_blocks={segment_resumed} "
          f"cluster={t_cluster:.1f}s "
          f"shared={t_shared:.1f}s steploop={t_steploop:.1f}s "
          f"steploop_host_kernel_ratio={steploop_ratio:.2f} "
          f"frontend={t_frontend:.1f}s "
          f"frontend_ttft_p99_ms={frontend_p99 * 1e3:.0f} "
          f"chaos={t_chaos:.1f}s "
          f"chaos_retries={chaos_retries} "
          f"chaos_integrity_catches={chaos_integrity} "
          f"total={elapsed:.1f}s "
          f"budget={budget_s:.0f}s" + (" (disabled)" if not budget_s else ""))
    print(f"pytest -m 'not slow' wall: "
          + (f"{float(tier1_s):.0f}s" if tier1_s else "n/a (TIER1_WALL_S unset)"))
    # wall-clock budget: ~2x the compiled-backend baseline on a CI
    # runner — an interpret-mode fallback (or an equivalent wall-clock
    # regression) blows well past it
    assert not budget_s or elapsed <= budget_s, (
        f"replay smoke took {elapsed:.1f}s > budget {budget_s:.0f}s — "
        f"kernel backend {default_backend()!r}; did the compiled xla "
        f"fallback regress to interpret mode?")


if __name__ == "__main__":
    main()
