#!/usr/bin/env python3
"""CI smoke for the trace→engine serving replay: a tiny agentic trace
(2 sessions x 2 turns) through the live ServingEngine, asserting the
harness completes and produces sane accounting — then the same trace
through a 2-replica ReplicaCluster with a mid-replay failover,
asserting every turn still completes and the redispatch/re-prefill
accounting is consistent.

The smoke also enforces a wall-clock budget (``REPLAY_SMOKE_BUDGET_S``,
0/unset disables): under the compiled ``xla`` kernel backend the whole
script is a few times faster than the old interpret-mode path, and the
budget catches a silent fall-back to the interpreter (or any comparable
wall-clock regression) in CI.  A ``smoke summary`` line with the
resolved backend and per-phase timings is printed for the job log.

    PYTHONPATH=src python scripts/replay_smoke.py
"""
import os
import time

from repro.kernels.backend import default_backend
from repro.traces.serving_replay import (ClusterReplayConfig,
                                         ServingReplayConfig,
                                         run_cluster_replay,
                                         run_serving_replay)


def single_engine_smoke() -> None:
    r = run_serving_replay(ServingReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2, max_turns=2,
        max_steps=500))
    assert r.requests_done > 0, "no turns completed"
    assert r.generated_tokens > 0, "no tokens generated"
    assert 0.0 <= r.engine_hit_rate <= 1.0
    assert r.engine_hit_rate <= r.reuse_rate
    assert r.virtual_time_s > 0.0
    print(f"replay smoke ok: {r.requests_done} turns, "
          f"hit {100 * r.engine_hit_rate:.1f}%, "
          f"reuse {100 * r.reuse_rate:.1f}%, "
          f"wall {r.wall_s:.1f}s")


def cluster_smoke() -> None:
    """2 replicas x 2 sessions, round-robin (both replicas guaranteed
    traffic), one replica killed after the first completed turn — the
    failover path must redispatch and still finish every turn."""
    r = run_cluster_replay(ClusterReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2, max_turns=2,
        n_replicas=2, routing="round_robin", fail_replica_after_turns=1,
        max_steps=500))
    assert r.requests_done == 4, f"expected 4 turns, got {r.requests_done}"
    assert len(r.failed_replicas) == 1
    assert r.redispatched >= 0 and r.reprefill_tokens >= 0
    assert (r.redispatched == 0) == (r.reprefill_tokens == 0)
    assert 0.0 <= r.fleet_hit_rate <= r.fleet_reuse_rate <= 1.0
    assert sum(p.requests_done for p in r.per_replica) == r.requests_done
    assert r.virtual_time_s > 0.0
    print(f"cluster smoke ok: {r.requests_done} turns on "
          f"{r.n_replicas} replicas ({len(r.failed_replicas)} failed), "
          f"fleet hit {100 * r.fleet_hit_rate:.1f}%, "
          f"redispatched {r.redispatched}, "
          f"re-prefilled {r.reprefill_tokens} tokens, "
          f"wall {r.wall_s:.1f}s")


def main() -> None:
    budget_s = float(os.environ.get("REPLAY_SMOKE_BUDGET_S", "0"))
    t0 = time.perf_counter()
    single_engine_smoke()
    t_single = time.perf_counter() - t0
    t1 = time.perf_counter()
    cluster_smoke()
    t_cluster = time.perf_counter() - t1
    elapsed = time.perf_counter() - t0
    print(f"smoke summary: kernel_backend={default_backend()} "
          f"single={t_single:.1f}s cluster={t_cluster:.1f}s "
          f"total={elapsed:.1f}s "
          f"budget={budget_s:.0f}s" + (" (disabled)" if not budget_s else ""))
    # wall-clock budget: ~2x the compiled-backend baseline on a CI
    # runner — an interpret-mode fallback (or an equivalent wall-clock
    # regression) blows well past it
    assert not budget_s or elapsed <= budget_s, (
        f"replay smoke took {elapsed:.1f}s > budget {budget_s:.0f}s — "
        f"kernel backend {default_backend()!r}; did the compiled xla "
        f"fallback regress to interpret mode?")


if __name__ == "__main__":
    main()
