#!/usr/bin/env python3
"""CI smoke for the trace→engine serving replay: a tiny agentic trace
(2 sessions x 2 turns) through the live ServingEngine, asserting the
harness completes and produces sane accounting.

    PYTHONPATH=src python scripts/replay_smoke.py
"""
from repro.traces.serving_replay import (ServingReplayConfig,
                                         run_serving_replay)


def main() -> None:
    r = run_serving_replay(ServingReplayConfig(
        workload="agentic", policy="bayesian", n_sessions=2, max_turns=2,
        max_steps=500))
    assert r.requests_done > 0, "no turns completed"
    assert r.generated_tokens > 0, "no tokens generated"
    assert 0.0 <= r.engine_hit_rate <= 1.0
    assert r.engine_hit_rate <= r.reuse_rate
    assert r.virtual_time_s > 0.0
    print(f"replay smoke ok: {r.requests_done} turns, "
          f"hit {100 * r.engine_hit_rate:.1f}%, "
          f"reuse {100 * r.reuse_rate:.1f}%, "
          f"wall {r.wall_s:.1f}s")


if __name__ == "__main__":
    main()
