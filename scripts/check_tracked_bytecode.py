#!/usr/bin/env python3
"""CI guard: fail if compiled Python bytecode is tracked by git.

``src/repro/__pycache__/*.pyc`` files were committed once (PR 2) and
later removed; ``.gitignore`` keeps new ones out of ``git add .``, but
nothing stopped an explicit ``git add -f`` from re-introducing them.
This check makes the regression a CI failure instead of a review catch.

    python scripts/check_tracked_bytecode.py
"""
import re
import subprocess
import sys

PATTERN = re.compile(r"(^|/)__pycache__(/|$)|\.py[cod]$|\.so$")


def main() -> int:
    files = subprocess.run(
        ["git", "ls-files"], capture_output=True, text=True, check=True,
    ).stdout.splitlines()
    bad = [f for f in files if PATTERN.search(f)]
    if bad:
        print("tracked bytecode/compiled artifacts (git rm --cached them):")
        for f in bad:
            print(f"  {f}")
        return 1
    print(f"no tracked bytecode ({len(files)} tracked files clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
